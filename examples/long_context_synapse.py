"""Long-context decode with the streaming Topological Synapse.

Demonstrates the beyond-paper extension that unlocks the long_500k shape:
O(K+W) decode memory regardless of stream length, with hybrid
density-coverage eviction. Compares live cache bytes vs a full cache.

    PYTHONPATH=src python examples/long_context_synapse.py
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.prism import tree_bytes
from repro.models import cache as cache_lib, model as model_lib


def main():
    cfg = get_config("qwen3-8b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    B, steps = 1, 300
    spec = model_lib.CacheSpec(kind="synapse", n_landmarks=32, window=32, n_inject=4)
    caches = model_lib.init_caches(cfg, B, spec)
    syn_bytes = tree_bytes(caches)

    tokens = jax.random.randint(jax.random.key(1), (B, steps), 0, cfg.vocab_size)
    step = jax.jit(
        lambda p, t, pos, c: model_lib.decode_step(
            p, cfg, {"tokens": t, "positions": pos}, c, spec=spec
        )
    )
    for t in range(steps):
        logits, _, caches = step(params, tokens[:, t], jnp.full((B,), t, jnp.int32), caches)

    lm_pos = np.asarray(caches.groups[0].lm_pos)[0, 0]
    lm_count = int(np.asarray(caches.groups[0].lm_count)[0, 0])
    full_equiv = cache_lib.cache_bytes(cache_lib.init_full_cache(cfg, B, steps)) * cfg.n_layers
    print(f"[long-context] decoded {steps} tokens with O(K+W) cache")
    print(f"  synapse cache bytes : {syn_bytes/1e6:.2f} MB (constant in stream length)")
    print(f"  full cache at {steps}: {full_equiv/1e6:.2f} MB (grows linearly)")
    print(f"  landmarks kept      : {lm_count}, positions span "
          f"[{lm_pos[:lm_count].min()}, {lm_pos[:lm_count].max()}]")
    print(f"  last logits finite  : {bool(jnp.isfinite(logits).all())}")


if __name__ == "__main__":
    main()
