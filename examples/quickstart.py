"""Quickstart: batched serving of a small model with the public API.

    PYTHONPATH=src python examples/quickstart.py [--arch smollm-135m]

Uses the reduced (CPU-sized) variant of any assigned architecture.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, list_archs
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-0.5b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    print(f"[quickstart] arch={args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model})")
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    server = BatchServer(
        params, cfg, tok, n_lanes=4, capacity=256,
        sampling=SamplingParams(temperature=0.9, top_k=40),
    )
    # per-request sampling: greedy and exploratory requests batch into the
    # same decode + shared sampling pass (per-lane temperature/top-k/top-p)
    per_request = [
        SamplingParams(greedy=True),
        SamplingParams(temperature=0.7, top_k=20),
        SamplingParams(temperature=1.2, top_p=0.9),
        None,  # server default
    ]
    for i in range(args.requests):
        server.submit(
            f"request {i}: tell me something.", max_new_tokens=args.max_new_tokens,
            sampling=per_request[i % len(per_request)],
        )
    # pipelined drain (default): step t+1 is dispatched before step t's
    # tokens reach the host, so detokenize/EOS checks overlap device decode
    done = server.run_until_done()
    for r in done:
        mode = r.sampling or server.sampling
        print(f"[req {r.rid}] ({mode}) {r.prompt!r} -> {r.text!r}")
    st = server.stats
    print(f"[server] steps={st['steps']} overlapped={st['overlapped']} "
          f"rollbacks={st['rollbacks']}")


if __name__ == "__main__":
    main()
