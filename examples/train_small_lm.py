"""End-to-end training driver: train a small LM for a few hundred steps on
the synthetic corpus, checkpoint, and sample from it.

    PYTHONPATH=src python examples/train_small_lm.py [--steps 200]

(The paper is a serving paper — council_of_agents.py is the headline
end-to-end driver — but the training substrate is first-class: this example
exercises data pipeline -> train loop -> checkpoint -> serve.)
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_small_lm.msgpack.zst")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    state = init_train_state(jax.random.key(0), cfg)
    opt = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, opt))

    t0 = time.time()
    for i in range(args.steps):
        batch = {
            k: jnp.asarray(v)
            for k, v in make_batch(cfg, DataConfig(seq_len=args.seq, batch_size=args.batch, seed=i)).items()
        }
        state, m = step(state, batch)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  lr {float(m['lr']):.2e}  "
                  f"gnorm {float(m['grad_norm']):.2f}  ({time.time()-t0:.0f}s)")

    ckpt.save(args.ckpt, state.params)
    print(f"checkpoint -> {args.ckpt} ({os.path.getsize(args.ckpt)/1e6:.1f} MB)")

    restored = ckpt.load(args.ckpt, state.params)
    tok = ByteTokenizer(cfg.vocab_size)
    server = BatchServer(restored, cfg, tok, n_lanes=2, capacity=256,
                         sampling=SamplingParams(temperature=0.7, top_k=20))
    server.submit("12+34=", max_new_tokens=12)
    server.submit("abcde|", max_new_tokens=12)
    for r in server.run_until_done():
        print(f"sample: {r.prompt!r} -> {r.text!r}")


if __name__ == "__main__":
    main()
