"""Council of Agents — the paper's headline scenario, end to end.

A main "River" agent generates; [TASK: ...] triggers spawn side "Stream"
agents that reason over a landmark-compressed snapshot of the river's
context (Topological Synapse), pass the Validation Gate, and merge back via
Referential Injection — all sharing ONE copy of the weights (the Prism).

    PYTHONPATH=src python examples/council_of_agents.py
"""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams


def main():
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    prism = Prism(params, cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    engine = CortexEngine(
        prism,
        tok,
        n_main=2,
        max_side=4,
        main_capacity=512,
        side_max_steps=12,
        inject_tokens=8,
        theta=-1.0,  # untrained weights: accept all merges for the demo
        sampling=SamplingParams(temperature=1.0),
        # per-lane sampling: freshly spawned streams explore by default...
        side_sampling=SamplingParams(temperature=1.1, top_k=40),
        sync_every=4,  # ...and whole 4-tick windows ride ONE scanned dispatch
        # quiet drains lengthen the window up to 16 ticks/dispatch, and the
        # pipelined drain (default) overlaps each window's router/decode
        # host work with the device's next window
        max_window=16,
    )
    # ...while river 0 decodes greedily — per-lane params share the dispatch
    engine.submit(
        "Research question: why is the sky blue? [TASK: check Rayleigh scattering] "
        "Let me think step by step.",
        lane=0,
        sampling=SamplingParams(greedy=True),
    )
    engine.submit("Second river: summarize the meeting notes. [TASK: list action items] ok", lane=1)

    for chunk in range(5):  # 5 pipelined chunks == 40 virtual ticks
        engine.run(8)  # windows lengthen + drains overlap inside each chunk
        if chunk % 2 == 1:
            rep = engine.memory_report()
            st = engine.stats
            print(
                f"[tick {st['ticks']:3d}] agents={rep['n_agents']} "
                f"dispatches={st['tick_dispatches']} "
                f"(ticks/dispatch={st['ticks']/max(st['tick_dispatches'],1):.1f} "
                f"overlapped_drains={st['overlapped_drains']} "
                f"windows={st['window_hist']}) "
                f"weights={rep['weight_bytes']/1e6:.1f}MB "
                f"ctx/agent={rep['context_bytes_per_agent']/1e6:.2f}MB "
                f"total={rep['total_bytes']/1e6:.1f}MB "
                f"(standard-arch counterfactual: {rep['standard_architecture_bytes']/1e6:.1f}MB)"
            )

    print("\n--- event log ---")
    for e in engine.history:
        print(e)
    print("\n--- river 0 text (tail) ---")
    print(repr(engine.mains[0].text[-120:]))


if __name__ == "__main__":
    main()
