"""Paper Table 2: measured memory vs agent count.

We allocate REAL synapse caches (the paper's k=64 landmark geometry, full
qwen2.5-0.5b layer geometry) for N in {1, 10, 50, 100} agents and report
exact live bytes — the CPU-measurable equivalent of nvidia-smi deltas.
Weights are counted once (bf16); per-agent delta is pure context.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.prism import tree_bytes
from repro.models import cache as cache_lib

GB = 1 << 30


def run() -> dict:
    cfg = get_config("qwen2.5-0.5b")
    w_bytes = cfg.param_count() * 2  # bf16 weights, counted once (Prism)
    results = {}
    base = None
    for n_agents in (1, 10, 50, 100):
        # one stacked synapse cache per layer, batched over agents — REAL arrays
        caches = [
            cache_lib.init_synapse_cache(cfg, n_agents, n_landmarks=64, window=64, n_inject=8)
            for _ in range(cfg.n_layers)
        ]
        ctx_bytes = sum(tree_bytes(c) for c in caches)
        total = w_bytes + ctx_bytes
        if base is None:
            base = total
        per_agent = ctx_bytes / n_agents
        emit(
            f"table2.agents_{n_agents}",
            0,
            f"total={total/GB:.3f}GB delta={(total-base)/GB:.3f}GB per_agent={per_agent/1e6:.1f}MB",
        )
        results[n_agents] = {
            "total_gb": total / GB,
            "delta_gb": (total - base) / GB,
            "per_agent_mb": per_agent / 1e6,
        }
        del caches
    return results


if __name__ == "__main__":
    run()
