"""Lane-sharded macro-tick scaling — the ISSUE 6 per-lane-cost curve.

Times the fused cortex window under ``shard_map`` on an 8-way ``lane`` mesh
as ``n_side`` scales (64, 256 live; 1024 compiles via ``launch/dryrun.py
--lane``). The claim being measured: side state shards over the mesh, so the
marginal cost of a side lane (``per_lane_cost_s = tick_s / (1 + n_side)``)
falls as lanes spread across devices instead of stacking on one.

Must run in its OWN process: the forced-device-count XLA flag is read once
at jax import, so this module keeps every jax import inside :func:`run` and
the CLI sets ``XLA_FLAGS`` before touching it. ``benchmarks/run.py`` invokes
it as a subprocess (``--lane``) and folds the JSON into
``BENCH_throughput.json``.
"""
from __future__ import annotations

import argparse
import json
import os


def force_host_devices(n: int = 8) -> None:
    """Append the forced-device-count flag (idempotent). Call BEFORE any
    jax import in the process — the flag is read once at backend init."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        )


def run(n_sides=(64, 256), *, sync_every: int = 8, reps: int = 6,
        warmup_windows: int = 2, mesh_devices: int = 8) -> dict:
    import time

    import jax

    from benchmarks.common import emit
    from repro.configs import get_config
    from repro.core.engine import CortexEngine
    from repro.core.prism import Prism
    from repro.data.tokenizer import ByteTokenizer
    from repro.launch.mesh import make_lane_mesh
    from repro.models import model as model_lib
    from repro.serving.sampler import SamplingParams

    if jax.device_count() < mesh_devices:
        raise RuntimeError(
            f"need {mesh_devices} devices, have {jax.device_count()} — "
            "run via `python benchmarks/bench_lane_scale.py` (the CLI forces "
            "the host device count) or set XLA_FLAGS yourself"
        )
    mesh = make_lane_mesh(mesh_devices)
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)

    out = {
        "lane_mesh_shape": [mesh_devices],
        "sync_every": sync_every,
        "per_n_side": {},
    }
    for n_side in n_sides:
        eng = CortexEngine(
            Prism(params, cfg), tok, n_main=1, max_side=n_side,
            main_capacity=256, side_max_steps=100_000, inject_tokens=8,
            theta=2.0,  # never merge: lane population stays fixed while timing
            sampling=SamplingParams(temperature=1.0), sync_every=sync_every,
            mesh=mesh,
        )
        m = eng.submit("lane scaling benchmark prompt", lane=0)
        # fill every lane directly (a prompt carrying n_side task tags would
        # blow the main context at 256 sides)
        for i in range(n_side):
            assert eng._spawn_side(m, f"think {i}") is not None, i
        active = sum(s.active for s in eng.sides)
        assert active == n_side, (active, n_side)
        eng.run(warmup_windows * sync_every)  # compile macro tick + drain path
        stats0 = dict(eng.stats)
        dt = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            eng.run(sync_every)  # one fused window per timed chunk
            jax.block_until_ready(eng.state.main_ring)
            dt = min(dt, (time.perf_counter() - t0) / sync_every)
        dticks = eng.stats["ticks"] - stats0["ticks"]
        dispatches = eng.stats["tick_dispatches"] - stats0["tick_dispatches"]
        assert dispatches * sync_every == dticks, (dispatches, dticks)
        per_lane = dt / (1 + n_side)
        emit(
            f"lane_scale.sides_{n_side}",
            dt * 1e6,
            f"per_lane={per_lane*1e6:.1f}us mesh={mesh_devices} "
            f"dispatches/tick={dispatches/dticks:.3f}",
        )
        out["per_n_side"][n_side] = {
            "tick_s": dt,
            "per_lane_cost_s": per_lane,
            "active": active,
            "dispatches_per_tick": dispatches / dticks,
        }
    return out


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI variant: n_side=8, short windows")
    ap.add_argument("--out", default=None, help="write results JSON here")
    args = ap.parse_args()

    force_host_devices(8)
    # support `python benchmarks/bench_lane_scale.py` from the repo root
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, root)
    sys.path.insert(0, os.path.join(root, "src"))

    if args.smoke:
        res = run(n_sides=(8,), sync_every=4, reps=2, warmup_windows=1)
    else:
        res = run()
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
