"""Paper Table 1: theoretical VRAM comparison (0.5B model, 24 GB card).

Derived entirely from exact byte accounting of the real qwen2.5-0.5b config
(bf16 weights, 4k context full cache vs k=64 synapse).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import cache as cache_lib

GB = 1 << 30
CARD = 24 * GB


def run() -> dict:
    cfg = get_config("qwen2.5-0.5b")
    w_bytes = cfg.param_count() * 2  # bf16
    full = cache_lib.cache_bytes(cache_lib.init_full_cache(cfg, 1, 32768)) * cfg.n_layers
    syn = cache_lib.cache_bytes(
        cache_lib.init_synapse_cache(cfg, 1, n_landmarks=64, window=64, n_inject=8)
    ) * cfg.n_layers

    std_max = int((CARD - w_bytes) // (w_bytes + full))   # each agent: weights + full ctx
    wc_max = int((CARD - w_bytes) // syn)                 # shared weights + synapse each

    emit("table1.main_weights_gb", 0, f"{w_bytes/GB:.2f}")
    emit("table1.side_agent_weights_gb.standard", 0, f"{w_bytes/GB:.2f}")
    emit("table1.side_agent_weights_gb.warp_cortex", 0, "0.00 (shared)")
    emit("table1.side_agent_context_gb.standard", 0, f"{full/GB:.3f} (32k full)")
    emit("table1.side_agent_context_gb.warp_cortex", 0, f"{syn/GB:.4f} (synapse)")
    emit("table1.max_agents_24gb.standard", 0, str(std_max))
    emit("table1.max_agents_24gb.warp_cortex", 0, str(wc_max))
    return {
        "weights_gb": w_bytes / GB,
        "full_ctx_gb": full / GB,
        "synapse_gb": syn / GB,
        "max_agents_standard": std_max,
        "max_agents_warp_cortex": wc_max,
    }


if __name__ == "__main__":
    run()
