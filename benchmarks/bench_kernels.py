"""Kernel micro-bench: synapse_attention / landmark_score vs jnp reference.

CPU container: the Pallas kernels run in interpret mode, so absolute times
are NOT TPU times — reported for harness completeness; the jnp reference
numbers are the meaningful CPU datapoints.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref


def run() -> dict:
    out = {}
    for (B, H, Hkv, D, T) in [(4, 16, 4, 128, 1024), (8, 32, 8, 128, 4096)]:
        ks = jax.random.split(jax.random.key(0), 4)
        q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
        keys = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32)
        vals = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.float32)
        valid = jnp.ones((B, T), bool)
        lm = jax.random.normal(ks[3], (B, 64, D), jnp.float32)

        jref = jax.jit(ref.synapse_attention_ref)
        us_ref = time_fn(jref, q, keys, vals, valid, iters=5)
        emit(f"kernel.synapse_attention.ref.B{B}T{T}", us_ref, "jnp oracle (CPU)")
        us_int = time_fn(lambda *a: ops.synapse_attention(*a), q, keys, vals, valid, iters=2)
        emit(f"kernel.synapse_attention.pallas_interpret.B{B}T{T}", us_int, "interpret mode")

        jref2 = jax.jit(ref.landmark_score_ref)
        us_ref2 = time_fn(jref2, q, keys, lm, iters=5)
        emit(f"kernel.landmark_score.ref.B{B}T{T}", us_ref2, "jnp oracle (CPU)")
        out[f"B{B}T{T}"] = {"attn_ref_us": us_ref, "score_ref_us": us_ref2}
    return out


if __name__ == "__main__":
    run()
