"""Tiered synapse memory benchmark (ISSUE 7): many registered, few active.

Fills an engine with ``registered`` agents over ``active`` main lanes —
every over-subscription hibernates the LRU resident into the SynapseStore
(warm host RAM, spilling to cold zstd disk under `warm_capacity_bytes`) —
then measures:

* per-tier byte occupancy (hot device / warm host / cold disk) and the
  registered-vs-active agent split, straight from `memory_report()`;
* wake-to-first-token latency: hibernate a resident to free a lane, start
  the async `wake()` prefetch, and time until the woken agent's stream
  grows by one token inside a normal `run()` window.

The dormant-agent claim this records is the paper's capacity argument: a
registered-but-inactive agent costs ZERO device bytes (asserted by
`benchmarks/run.py --smoke` via :func:`assert_dormant_zero`).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.memory import HIBERNATED, SynapseStore
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams


def _build(registered: int, active: int, *, sync_every: int, store: SynapseStore,
           ticks_every: int, params=None):
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    if params is None:
        params = model_lib.init_params(jax.random.key(0), cfg)
    eng = CortexEngine(
        Prism(params, cfg), ByteTokenizer(cfg.vocab_size), n_main=active,
        max_side=2, main_capacity=128, side_max_steps=6, inject_tokens=8,
        theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=sync_every, store=store,
    )
    for i in range(registered):
        eng.submit_agent(f"agent {i} ponders its corner of the problem",
                         agent_id=f"agent{i:04d}")
        if ticks_every and (i + 1) % ticks_every == 0:
            eng.run(sync_every)
    eng.run(sync_every)
    return eng


def assert_dormant_zero(rep: dict, registered: int, active: int) -> None:
    """The acceptance bar: every dormant agent contributes exactly zero
    device bytes — only the ``active`` lane-holders appear in the per-agent
    device accounting; everything else lives in warm/cold tiers."""
    per_agent = rep["per_agent_bytes"]
    # only the active lane-holders have device entries: a dormant agent's
    # device footprint is not "small", it is absent — exactly zero bytes
    assert len(per_agent) == active, (len(per_agent), active)
    assert all(b > 0 for b in per_agent.values())
    assert rep["agents"]["registered"] == registered
    assert rep["agents"]["active"] == active
    assert rep["agents"]["dormant"] == registered - active
    assert rep["tiers"]["warm_bytes"] + rep["tiers"]["cold_raw_bytes"] > 0
    assert rep["tiers"]["hot_bytes"] == sum(per_agent.values())


def run(*, registered: int = 256, active: int = 8, sync_every: int = 8,
        wake_reps: int = 5, ticks_every: int = 32, cold_spill: bool = True,
        params=None) -> dict:
    store = SynapseStore()
    eng = _build(registered, active, sync_every=sync_every, store=store,
                 ticks_every=ticks_every, params=params)
    rep = eng.memory_report()
    assert_dormant_zero(rep, registered, active)

    snap_bytes = rep["tiers"]["warm_bytes"] // max(1, rep["tiers"]["n_warm"])
    if cold_spill and store.cold_enabled is False and store.cold_dir is None:
        # enable the cold tier post-hoc only to measure spill accounting;
        # without zstandard this stays a no-op and the report says so
        store.cold_dir = "benchmarks/artifacts/hibernate_cold"
    if cold_spill and store.cold_enabled:
        # spill half the dormant set so both tiers show up in the report
        store.warm_capacity_bytes = snap_bytes * max(1, (registered - active) // 2)
        with store._lock:
            store._enforce_capacity_locked()
        rep = eng.memory_report()

    # cold-read integrity overhead (ISSUE 8): every production cold read
    # verifies the frame checksum; A/B the same blob with verification on
    # vs off (PR 7's unverified behavior) to price the resilience layer
    verify_ab = None
    cold_keys = [k for k in store.keys() if store.tier_of(k) == "cold"]
    if cold_keys:
        key, reps = cold_keys[0], 20
        for arm in ("verify", "noverify"):
            store.get_host(key, verify=arm == "verify")  # warm the page cache
        t0 = time.perf_counter()
        for _ in range(reps):
            store.get_host(key, verify=True)
        verify_s = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            store.get_host(key, verify=False)
        noverify_s = (time.perf_counter() - t0) / reps
        verify_ab = {
            "cold_read_verify_s": verify_s,
            "cold_read_noverify_s": noverify_s,
            "verify_overhead_s": verify_s - noverify_s,
            "verify_overhead_frac": (verify_s - noverify_s) / max(noverify_s, 1e-12),
            "blob_bytes": store.report()["cold_bytes"] // max(1, len(cold_keys)),
        }
        emit("hibernate.cold_read_verify_overhead", (verify_s - noverify_s) * 1e6,
             f"verify={verify_s*1e6:.0f}us noverify={noverify_s*1e6:.0f}us "
             f"(+{100 * verify_ab['verify_overhead_frac']:.1f}%)")

    # wake-to-first-token: free a lane, then promote the LRU dormant agent
    wakes = []
    for _ in range(wake_reps):
        eng.hibernate(eng.registry.lru_active("main").agent_id)
        target = min(eng.registry.with_status(HIBERNATED, "main"),
                     key=lambda r: r.last_event)
        view, tier = target.saved["view"], store.tier_of(target.agent_id)
        n0 = len(view.tokens)
        t0 = time.perf_counter()
        eng.wake(target.agent_id)
        while len(view.tokens) == n0:  # first post-wake token lands mid-run
            eng.run(sync_every)
        wakes.append({"s": time.perf_counter() - t0, "tier": tier})
    lat = sorted(w["s"] for w in wakes)
    wake_s = lat[len(lat) // 2]
    emit("hibernate.wake_to_first_token", wake_s * 1e6,
         f"registered={registered} active={active} "
         f"warmMB={rep['tiers']['warm_bytes']/1e6:.1f} "
         f"coldMB={rep['tiers']['cold_bytes']/1e6:.2f}")

    final = eng.memory_report()
    return {
        "registered": registered,
        "active": active,
        "sync_every": sync_every,
        "per_agent_snapshot_bytes": snap_bytes,
        "tiers": final["tiers"],
        "agents": final["agents"],
        "weight_bytes": final["weight_bytes"],
        "cold_enabled": store.cold_enabled,
        "store_stats": dict(store.stats),
        "hibernates": eng.stats["hibernates"],
        "wakes": eng.stats["wakes"],
        "wake_to_first_token_s": wake_s,
        "wake_samples": wakes,
        "cold_read_verify": verify_ab,
    }
