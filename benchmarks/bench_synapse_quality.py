"""Paper §3.3 claim: "98% context compression without semantic loss".

Quantified: train a small model briefly on the copy task (so attention has
real structure), then compare full-cache decode vs synapse decode at several
compression ratios. Metrics: next-token argmax agreement and logit MAE,
hybrid (paper) vs density-only vs window-only (H2O-style) vs random-landmark
ablations.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import synapse as synapse_lib
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model as model_lib
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import init_train_state, make_train_step


def _train_small(steps: int = 60):
    cfg = dataclasses.replace(
        get_config("smollm-135m", reduced=True), compute_dtype="float32"
    )
    state = init_train_state(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps)))
    for i in range(steps):
        batch = {
            k: jnp.asarray(v)
            for k, v in make_batch(cfg, DataConfig(seq_len=64, batch_size=8, seed=i, mix=(0.7, 0.2, 0.1))).items()
        }
        state, m = step(state, batch)
    return cfg, state.params, float(m["loss"])


def _fidelity(cfg, params, spec, tok, logits_ref, P, S):
    B = tok.shape[0]
    caches = model_lib.init_caches(cfg, B, spec)
    lg, _, caches = model_lib.prefill(params, cfg, {"tokens": tok[:, :P]}, caches, spec=spec)
    agree, mae, n = 0, 0.0, 0
    for t in range(P, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg, _, caches = model_lib.decode_step(
            params, cfg, {"tokens": tok[:, t], "positions": pos}, caches, spec=spec
        )
        agree += int((jnp.argmax(lg, -1) == jnp.argmax(logits_ref[:, t], -1)).sum())
        mae += float(jnp.abs(lg - logits_ref[:, t]).mean())
        n += B
    return agree / n, mae / (S - P)


def run() -> dict:
    cfg, params, final_loss = _train_small()
    B, S = 4, 64
    batch = make_batch(cfg, DataConfig(seq_len=S, batch_size=B, seed=999, mix=(1.0, 0.0, 0.0)))
    tok = jnp.asarray(batch["tokens"])
    logits_ref, _ = model_lib.forward(params, cfg, {"tokens": tok})
    P = S - 16
    out = {"train_loss": final_loss}
    for k, w in [(48, 16), (24, 8), (12, 4), (6, 2)]:
        ratio = max(0.0, 1.0 - (k + w) / P)  # <=0: lossless control
        for name, policy in [
            ("hybrid", synapse_lib.SynapsePolicy(alpha=0.5)),
            ("density", synapse_lib.SynapsePolicy(alpha=1.0)),
            ("coverage", synapse_lib.SynapsePolicy(alpha=0.0)),
        ]:
            spec = model_lib.CacheSpec(kind="synapse", n_landmarks=k, window=w, n_inject=1, policy=policy)
            agree, mae = _fidelity(cfg, params, spec, tok, logits_ref, P, S)
            emit(
                f"synapse_quality.k{k}w{w}.{name}",
                0,
                f"compression={ratio:.0%} argmax_agree={agree:.3f} logit_mae={mae:.4f}",
            )
            out[f"k{k}_{name}"] = {"compression": ratio, "agree": agree, "mae": mae}
    return out


if __name__ == "__main__":
    run()
