"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Roofline/dry-run artifacts
(benchmarks/artifacts/) are produced by launch/dryrun.py + launch/roofline.py
(they need 512 host devices and run as separate processes).

``--smoke`` runs one reduced throughput iteration (CI-sized: a couple of
macro windows) and checks the macro-tick dispatch accounting without
touching the recorded BENCH_throughput.json baseline. ``--lane`` adds the
lane-sharded curve (bench_lane_scale) — a subprocess, because the forced
host-device count must be set before jax imports.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lane_bench(smoke: bool) -> dict:
    """Run bench_lane_scale in a forced-8-device subprocess and load its
    JSON. The parent process stays single-device (its jax backend is
    already initialized), so the lane curve cannot run in-process."""
    name = "bench_lane_smoke.json" if smoke else "bench_lane.json"
    out_path = os.path.join(ROOT, "benchmarks", "artifacts", name)
    cmd = [sys.executable, os.path.join(ROOT, "benchmarks", "bench_lane_scale.py"),
           "--out", out_path] + (["--smoke"] if smoke else [])
    subprocess.run(cmd, check=True, cwd=ROOT)
    with open(out_path) as f:
        return json.load(f)


def lane_smoke() -> dict:
    """CI gate for the sharded path: the curve must come off a real 8-way
    lane mesh with the macro-tick dispatch accounting intact."""
    res = lane_bench(smoke=True)
    assert res["lane_mesh_shape"] == [8], res
    for n_side, row in res["per_n_side"].items():
        assert row["tick_s"] > 0
        assert row["per_lane_cost_s"] > 0
        assert row["dispatches_per_tick"] == 1.0 / res["sync_every"], (n_side, row)
    print("smoke,ok,lane-sharded dispatch accounting verified")
    return res


def smoke() -> dict:
    """One reduced throughput iteration + the macro-tick dispatch-accounting
    assertions. Single source of truth: tests/test_bench_smoke.py calls this
    same function, so the CI script step and the pytest check cannot drift."""
    from benchmarks import bench_throughput

    out = bench_throughput.run(side_counts=(2,), ticks=4, warmup=4, sync_every=2,
                               ab_reps=3, adaptive_ticks=48)
    res = out["per_side"][2]
    assert res["tick_s"] > 0
    assert res["active"] == 2
    # macro engine: whole sync_every windows ride one scanned dispatch, so
    # the amortized dispatch rate is exactly 1/sync_every...
    assert res["dispatches_per_tick"] == 1.0 / out["sync_every"], res
    # ...equivalently, each dispatch advances sync_every virtual ticks
    assert res["ticks_per_dispatch"] == out["sync_every"], res
    assert res["macro_dispatches"] >= 1
    # drains every sync_every ticks -> at most 1/sync_every syncs per tick
    assert res["host_syncs_per_tick"] <= 1.0 / out["sync_every"] + 1e-9
    # pipelined drains: the A/B arm must actually overlap host work with
    # device windows (multi-window chunks), bitwise-parity asserted inside
    assert out["ab"]["overlap_fraction"] > 0, out["ab"]
    # adaptive windows: a trigger-free run lengthens past the base window
    # and drops the amortized dispatch rate below 1/sync_every
    ada = out["adaptive"]
    assert ada["longest_window"] > out["sync_every"], ada
    assert ada["dispatches_per_tick"] < 1.0 / out["sync_every"], ada
    assert ada["overlap_fraction"] > 0, ada
    os.makedirs("benchmarks/artifacts", exist_ok=True)
    with open("benchmarks/artifacts/bench_smoke.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    print("smoke,ok,macro-tick dispatch accounting verified")
    return out


def hibernate_smoke() -> dict:
    """CI gate for the tiered synapse memory (ISSUE 7): a dormant agent
    must cost exactly ZERO device bytes (`assert_dormant_zero` inside the
    bench), the registry split must add up, and the async wake must land a
    token. Sized small; the recorded baseline uses registered=256."""
    from benchmarks import bench_hibernate

    out = bench_hibernate.run(registered=16, active=4, sync_every=4,
                              wake_reps=2, ticks_every=8)
    assert out["agents"]["dormant"] == out["registered"] - out["active"]
    assert out["wake_to_first_token_s"] > 0
    assert out["wakes"] >= 2 and out["hibernates"] >= out["registered"] - out["active"]
    os.makedirs("benchmarks/artifacts", exist_ok=True)
    with open("benchmarks/artifacts/bench_hibernate_smoke.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    print("smoke,ok,dormant agents hold zero device bytes; async wake verified")
    return out


def chaos_smoke() -> dict:
    """CI gate for the resilience layer (ISSUE 8): a scripted fault storm —
    bit-flipped cold blob, transient read failures, a murdered prefetch
    worker — against hibernate/wake churn. The engine must degrade
    per-agent (permanent loss -> LOST, transient -> retried/rewoken), keep
    ticking, and leave untouched lanes bitwise identical to a fault-free
    engine. Writes the fault-injection report artifact."""
    import tempfile

    import jax

    from repro.configs import get_config
    from repro.core.engine import CortexEngine
    from repro.core.prism import Prism
    from repro.data.tokenizer import ByteTokenizer
    from repro.memory import ACTIVE, HIBERNATED, LOST, FaultInjector, SynapseStore
    from repro.models import model as model_lib
    from repro.serving.sampler import SamplingParams

    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    prompts = {"A": "agent A considers the first question at length.",
               "B": "agent B writes a careful second answer here.",
               "C": "agent C is the untouched control stream."}

    def build(store=None):
        eng = CortexEngine(Prism(params, cfg), tok, n_main=3, max_side=2,
                           main_capacity=128, theta=1e9, sync_every=4,
                           sampling=SamplingParams(greedy=True), store=store)
        for lane, (aid, p) in enumerate(prompts.items()):
            eng.submit(p, lane=lane, agent_id=aid)
        return eng

    ref = build()
    ref.run(32)
    ref_c = next(m for m in ref.mains if m.agent_id == "C").text

    faults = (
        FaultInjector()
        .flip_write("A")                          # permanent: A's blob corrupt on disk
        .fail_read("B", nth=1, times=2)           # transient: first wake retries through
        .kill_worker_on_read("B", nth=4)          # second wake murders the worker
    )
    cold = tempfile.mkdtemp(prefix="chaos_cold_")
    store = SynapseStore(warm_capacity_bytes=1, cold_dir=cold, faults=faults,
                         wake_backoff_s=0.001)
    eng = build(store)
    eng.run(16)
    eng.hibernate("A")
    eng.hibernate("B")
    eng.wake("A")   # corrupt blob -> quarantine -> LOST; engine keeps ticking
    eng.wake("B")   # two injected read failures -> retry -> lands
    eng.run(8)
    eng.flush_wakes()
    assert eng.registry.get("A").status == LOST, eng.registry.get("A").status
    assert eng.registry.get("B").status == ACTIVE, eng.registry.get("B").status
    assert store.stats["quarantined"] == 1 and store.stats["wake_retries"] == 2, store.stats
    # round 2: the prefetch worker dies mid-promotion; supervision must fail
    # the ticket (B stays HIBERNATED, re-wakeable), respawn, then succeed
    eng.hibernate("B")
    eng.wake("B")
    eng.run(4)
    eng.flush_wakes()
    assert eng.registry.get("B").status == HIBERNATED, eng.registry.get("B").status
    assert eng.stats["wake_failures"] >= 1 and store.stats["worker_respawns"] == 1
    eng.wake("B", wait=True)
    eng.run(4)
    eng.flush_wakes()
    assert eng.registry.get("B").status == ACTIVE
    # the control lane never noticed any of it: bitwise parity at tick 32
    assert eng.stats["ticks"] == 32, eng.stats["ticks"]
    chaos_c = next(m for m in eng.mains if m.agent_id == "C").text
    assert chaos_c == ref_c, (chaos_c[:60], ref_c[:60])
    assert eng.stats["lost_agents"] == 1 and eng.stats["wakes"] == 2

    out = {
        "faults": faults.report(),
        "store_stats": dict(store.stats),
        "engine_stats": {k: eng.stats[k] for k in
                         ("ticks", "hibernates", "wakes", "wake_failures",
                          "lost_agents", "host_syncs", "macro_dispatches")},
        "agents": eng.registry.counts(),
        "control_parity": True,
    }
    os.makedirs("benchmarks/artifacts", exist_ok=True)
    with open("benchmarks/artifacts/chaos_report.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    print("smoke,ok,chaos: transient faults retried, permanent loss degraded, "
          "control lane bitwise")
    return out


def serving_smoke() -> dict:
    """CI gate for the serving front-end (ISSUE 9): the `serving` bench
    section's key set must stay intact (TTFT + tick-latency percentiles,
    per-tenant token shares, fairness counters), the 4:1 weighted tenants
    must measure token shares within 10% of the weight ratio under
    saturation, and no tenant may starve."""
    from benchmarks import bench_serving

    out = bench_serving.run(per_tenant=40, budget=8, ticks=60)
    # key-set assertions: the section cannot silently rot
    assert {"p50", "p99"} <= set(out["ttft_s"]), out["ttft_s"]
    assert {"p50", "p99", "n"} <= set(out["tick_latency_s"])
    assert out["tick_latency_s"]["n"] > 0
    assert {"admission_rounds", "starvation_promotions",
            "starvation_rounds"} <= set(out["fairness"])
    for name, row in out["tenants"].items():
        assert {"weight", "token_share", "expected_share", "admitted",
                "rejected", "ttft_p50_s", "ttft_p99_s"} <= set(row), (name, row)
    # fairness acceptance: 4:1 weights -> shares within 10%, nobody starves
    for name, row in out["tenants"].items():
        assert row["share_error"] <= 0.10, (name, row)
        assert row["tokens_out"] > 0 and row["admitted"] > 0, (name, row)
    assert out["completed"] > 0 and out["ttft_s"]["p50"] > 0
    os.makedirs("benchmarks/artifacts", exist_ok=True)
    with open("benchmarks/artifacts/bench_serving_smoke.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    shares = {n: round(r["token_share"], 3) for n, r in out["tenants"].items()}
    print(f"smoke,ok,serving: weighted-fair shares {shares} within 10%; "
          "TTFT/tick-latency/fairness keys intact")
    return out


def transport_smoke() -> dict:
    """CI gate for the HTTP/SSE transport (ISSUE 10): the in-process vs
    loopback A/B must produce both legs with sane SLOs, every loopback
    stream must complete over a REAL socket (no disconnects, no stalled
    writes on a healthy client), and the `serving.transport` section's key
    set must stay intact."""
    from benchmarks import bench_serving

    out = bench_serving.transport_ab(n_lanes=2, n_requests=2, budget=8)
    assert {"in_process", "loopback", "overhead"} <= set(out), set(out)
    for leg in ("in_process", "loopback"):
        row = out[leg]
        assert {"ttft_s", "tpot_s", "wall_s", "tokens_per_s"} <= set(row)
        assert row["ttft_s"]["n"] == out["n_requests"], (leg, row["ttft_s"])
        assert row["ttft_s"]["p50"] > 0 and row["tokens_out"] > 0, (leg, row)
    ts = out["loopback"]["transport_stats"]
    assert ts["streams_ok"] == ts["streams_opened"] == out["n_requests"] + 1
    assert ts["disconnects"] == 0 and ts["stalled_writes"] == 0, ts
    assert {"ttft_p50_ms", "tpot_p50_us"} <= set(out["overhead"])
    os.makedirs("benchmarks/artifacts", exist_ok=True)
    with open("benchmarks/artifacts/bench_transport_smoke.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"smoke,ok,transport: loopback SSE A/B complete, "
          f"ttft overhead {out['overhead']['ttft_p50_ms']:.2f}ms")
    return out


def main() -> None:
    from benchmarks import bench_kernels, bench_synapse_quality, bench_table1, bench_table2, bench_throughput

    print("name,us_per_call,derived")
    results = {}
    for name, mod in [
        ("table1", bench_table1),
        ("table2", bench_table2),
        ("synapse_quality", bench_synapse_quality),
        ("throughput", bench_throughput),
        ("kernels", bench_kernels),
    ]:
        try:
            results[name] = mod.run()
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},0,FAILED:{type(e).__name__}:{e}")
            results[name] = {"error": str(e)}
    os.makedirs("benchmarks/artifacts", exist_ok=True)
    with open("benchmarks/artifacts/bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)

    # top-level perf-trajectory artifact: tick latency per side-count plus
    # the engine's dispatch/sync counters, tracked across PRs. Never clobber
    # the recorded baseline with a failed run.
    throughput = results.get("throughput", {})
    if throughput and "error" not in throughput:
        try:
            lane = lane_bench(smoke=False)
            throughput["lane_mesh_shape"] = lane["lane_mesh_shape"]
            throughput["lane_scale"] = lane["per_n_side"]
        except Exception as e:
            print(f"lane_scale,0,FAILED:{type(e).__name__}:{e}")
        try:
            from benchmarks import bench_hibernate

            throughput["hibernate"] = bench_hibernate.run()
        except Exception as e:
            print(f"hibernate,0,FAILED:{type(e).__name__}:{e}")
        try:
            from benchmarks import bench_serving

            throughput["serving"] = bench_serving.run()
            # in-process vs loopback wire overhead (ISSUE 10)
            throughput["serving"]["transport"] = bench_serving.transport_ab()
        except Exception as e:
            print(f"serving,0,FAILED:{type(e).__name__}:{e}")
        with open(os.path.join(ROOT, "BENCH_throughput.json"), "w") as f:
            json.dump(throughput, f, indent=1, default=str)


if __name__ == "__main__":
    # support `python benchmarks/run.py` (CI) as well as `-m benchmarks.run`
    sys.path.insert(0, ROOT)
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CI pass; no baseline rewrite")
    ap.add_argument("--lane", action="store_true",
                    help="with --smoke: add the forced-8-device lane-mesh curve")
    ap.add_argument("--chaos", action="store_true",
                    help="with --smoke: run ONLY the fault-injection chaos "
                         "smoke (writes benchmarks/artifacts/chaos_report.json)")
    ap.add_argument("--serving", action="store_true",
                    help="with --smoke: run ONLY the serving front-end smoke "
                         "(weighted-fair shares + SLO key set)")
    ap.add_argument("--transport", action="store_true",
                    help="with --smoke: run ONLY the HTTP/SSE transport smoke "
                         "(loopback A/B, writes bench_transport_smoke.json)")
    args = ap.parse_args()
    if args.smoke:
        if args.chaos:
            chaos_smoke()
        elif args.serving:
            serving_smoke()
        elif args.transport:
            transport_smoke()
        else:
            smoke()
            hibernate_smoke()
            serving_smoke()
            transport_smoke()
            if args.lane:
                lane_smoke()
    else:
        main()
