"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Roofline/dry-run artifacts
(benchmarks/artifacts/) are produced by launch/dryrun.py + launch/roofline.py
(they need 512 host devices and run as separate processes).
"""
from __future__ import annotations

import json
import os


def main() -> None:
    from benchmarks import bench_kernels, bench_synapse_quality, bench_table1, bench_table2, bench_throughput

    print("name,us_per_call,derived")
    results = {}
    for name, mod in [
        ("table1", bench_table1),
        ("table2", bench_table2),
        ("synapse_quality", bench_synapse_quality),
        ("throughput", bench_throughput),
        ("kernels", bench_kernels),
    ]:
        try:
            results[name] = mod.run()
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},0,FAILED:{type(e).__name__}:{e}")
            results[name] = {"error": str(e)}
    os.makedirs("benchmarks/artifacts", exist_ok=True)
    with open("benchmarks/artifacts/bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)

    # top-level perf-trajectory artifact: tick latency per side-count plus
    # the engine's dispatch/sync counters, tracked across PRs. Never clobber
    # the recorded baseline with a failed run.
    throughput = results.get("throughput", {})
    if throughput and "error" not in throughput:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        with open(os.path.join(root, "BENCH_throughput.json"), "w") as f:
            json.dump(throughput, f, indent=1, default=str)


if __name__ == "__main__":
    main()
