"""Serving front-end SLO + fairness benchmark (ISSUE 9).

Drives the :class:`~repro.serving.frontend.ServingFrontend` over a
continuous-batching :class:`BatchServer` with two tenants at 4:1 weights
under sustained overload (the backlog outlives the measurement window), and
records the serving section of BENCH_throughput.json:

* per-tenant **token shares** — under saturation the weighted-fair queue
  must converge admissions (and hence served tokens) to the weight ratio;
* **TTFT** p50/p99 per tenant and overall, time-per-output-token, queue
  wait — the per-request SLO surface;
* **tick latency** p50/p99 — sampled from commit-callback timestamps, i.e.
  the cadence a streaming caller actually observes, pipelining included;
* **fairness counters** — admission rounds, starvation promotions (with
  the configured bound), per-tenant admitted/rejected.

The run is deliberately truncated (``ticks``): every request has the same
budget, so a run-to-completion would always end at the submitted ratio no
matter how unfair the schedule was. Shares are only meaningful measured
*during* contention.
"""
from __future__ import annotations

import time

import jax

from repro.configs import get_config
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.frontend import ServingFrontend
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer

PROMPTS = [
    "mixed script prompt é∑🚀 number {i}",
    "plain ascii prompt number {i}",
    "日本語のプロンプト {i}",
]


def run(*, n_lanes: int = 4, per_tenant: int = 40, budget: int = 16,
        ticks: int = 120, weights: dict[str, float] | None = None,
        starvation_rounds: int = 256) -> dict:
    # NOTE starvation_rounds: the whole backlog arrives at round 0 here, so a
    # tight bound would age EVERY head within ~bound admissions and the
    # schedule would (correctly) degrade to global FIFO — the bench would
    # then measure the bound, not WFQ convergence. A bound well past the
    # admissions in the window keeps the measurement on the weighted shares;
    # the low-weight tenant's nonzero share is the no-starvation evidence.
    weights = weights or {"gold": 4.0, "free": 1.0}
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    srv = BatchServer(params, cfg, ByteTokenizer(cfg.vocab_size),
                      n_lanes=n_lanes, capacity=128,
                      sampling=SamplingParams(greedy=True))
    fe = ServingFrontend(srv, tenants=weights, max_queue=4 * per_tenant,
                         starvation_rounds=starvation_rounds)
    for i in range(per_tenant):
        for tenant in weights:
            fe.submit(PROMPTS[i % len(PROMPTS)].format(i=i), tenant=tenant,
                      max_new_tokens=budget)
    t0 = time.perf_counter()
    # ONE bounded pipelined run: admissions ride the boundary hook as lanes
    # free up; the backlog must survive the window or shares degenerate to
    # the submitted ratio (asserted below)
    srv.run_until_done(max_ticks=ticks, pipeline=True)
    wall_s = time.perf_counter() - t0

    m = fe.metrics()
    for name, row in m["tenants"].items():
        # EVERY tenant must still hold backlog, or the drained one coasts on
        # leftover capacity and the measured share stops reflecting the policy
        assert row["queued"] > 0, f"{name} drained: shares no longer measure fairness"
    total = sum(t["tokens_out"] for t in m["tenants"].values())
    wsum = sum(weights.values())
    out = {
        "n_lanes": n_lanes,
        "ticks": ticks,
        "budget": budget,
        "wall_s": wall_s,
        "tokens_served": total,
        "tokens_per_s": total / wall_s if wall_s > 0 else 0.0,
        "completed": m["completed"],
        "ttft_s": m["ttft_s"],
        "tick_latency_s": m["tick_latency_s"],
        "fairness": m["fairness"],
        "tenants": {
            name: {
                **m["tenants"][name],
                "expected_share": weights[name] / wsum,
            }
            for name in weights
        },
    }
    for name, row in out["tenants"].items():
        row["share_error"] = abs(row["token_share"] - row["expected_share"])
    return out


def _pctl(xs) -> dict:
    from repro.serving.frontend import percentile

    return {"p50": percentile(xs, 50), "p99": percentile(xs, 99), "n": len(xs)}


def transport_ab(*, n_lanes: int = 4, n_requests: int = 4,
                 budget: int = 32) -> dict:
    """Transport-overhead A/B (ISSUE 10): the SAME request set consumed
    once through in-process :class:`TokenStream` handles and once over a
    loopback HTTP/SSE connection, with client-observed TTFT (submit to
    first text chunk) and TPOT ((last - first) / (tokens - 1)) for each
    leg. Each leg warms the jit caches off the clock; ``n_requests ==
    n_lanes`` keeps queue wait out of the comparison, so the delta is the
    wire path itself — recorded as ``serving.transport``."""
    import threading

    from repro.serving.transport import SSEClient, TransportServer

    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)

    def make_fe():
        srv = BatchServer(params, cfg, ByteTokenizer(cfg.vocab_size),
                          n_lanes=n_lanes, capacity=128,
                          sampling=SamplingParams(greedy=True))
        return ServingFrontend(srv, tenants={"t": 1.0})

    def leg_summary(recs, tokens, wall_s):
        ttfts = [r["first"] - r["start"] for r in recs if r["first"]]
        tpots = [(r["done"] - r["first"]) / (n - 1)
                 for r, n in zip(recs, tokens) if r["first"] and n > 1]
        total = sum(tokens)
        return {"ttft_s": _pctl(ttfts), "tpot_s": _pctl(tpots),
                "wall_s": wall_s, "tokens_out": total,
                "tokens_per_s": total / wall_s if wall_s > 0 else 0.0}

    prompts = [PROMPTS[i % len(PROMPTS)].format(i=i) for i in range(n_requests)]

    # -- leg A: in-process stream handles -------------------------------
    fe = make_fe()
    fe.submit("warmup", tenant="t", max_new_tokens=4)
    fe.serve()  # jit compile off the clock

    def consume(stream, rec):
        for _ in stream:
            now = time.perf_counter()
            if rec["first"] is None:
                rec["first"] = now
            rec["done"] = now

    recs, rids, threads = [], [], []
    t0 = time.perf_counter()
    for p in prompts:
        rec = {"start": time.perf_counter(), "first": None, "done": None}
        s = fe.submit(p, tenant="t", max_new_tokens=budget)
        th = threading.Thread(target=consume, args=(s, rec), daemon=True)
        th.start()
        recs.append(rec)
        rids.append(s.rid)
        threads.append(th)
    fe.serve()
    for th in threads:
        th.join(timeout=60)
    wall_a = time.perf_counter() - t0
    in_proc = leg_summary(recs, [fe.requests[r].tokens_out for r in rids],
                          wall_a)

    # -- leg B: the same set over loopback HTTP/SSE ---------------------
    fe2 = make_fe()
    with TransportServer(fe2) as srv:
        from repro.serving.transport import generate_sync

        generate_sync(srv.host, srv.port, "warmup", tenant="t",
                      max_new_tokens=4)

        def wire_client(prompt, rec, out):
            c = SSEClient(srv.host, srv.port)
            try:
                rec["start"] = time.perf_counter()
                status, _ = c.generate(prompt, tenant="t",
                                       max_new_tokens=budget)
                assert status == 200, status
                for ev in c.events():
                    now = time.perf_counter()
                    if "rid" in ev:
                        out["rid"] = ev["rid"]
                    elif "text" in ev:
                        if rec["first"] is None:
                            rec["first"] = now
                        rec["done"] = now
            finally:
                c.close()

        recs2 = [{"start": None, "first": None, "done": None}
                 for _ in prompts]
        outs = [{} for _ in prompts]
        threads = [threading.Thread(target=wire_client, args=(p, r, o),
                                    daemon=True)
                   for p, r, o in zip(prompts, recs2, outs)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120)
        wall_b = time.perf_counter() - t0
        loopback = leg_summary(
            recs2, [fe2.requests[o["rid"]].tokens_out for o in outs], wall_b
        )
        loopback["transport_stats"] = dict(srv.stats)

    return {
        "n_lanes": n_lanes,
        "n_requests": n_requests,
        "budget": budget,
        "in_process": in_proc,
        "loopback": loopback,
        "overhead": {
            "ttft_p50_ms": (loopback["ttft_s"]["p50"]
                            - in_proc["ttft_s"]["p50"]) * 1e3,
            "tpot_p50_us": (loopback["tpot_s"]["p50"]
                            - in_proc["tpot_s"]["p50"]) * 1e6,
        },
    }


if __name__ == "__main__":
    import json
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    out = run()
    out["transport"] = transport_ab()
    print(json.dumps(out, indent=1, default=str))
