"""Paper §5.2 "Performance Characteristics": graceful degradation — main
agent step latency as side agents scale.

On TPU side agents ride the same batched step (near-free until the batch
exhausts MXU headroom); on this CPU container they serialize, so we report
BOTH the measured wall numbers and the derived batched-cost model.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams


def run() -> dict:
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    out = {}
    base = None
    for n_side in (0, 2, 4, 8):
        prism = Prism(params, cfg)
        eng = CortexEngine(
            prism, tok, n_main=1, max_side=max(n_side, 1), main_capacity=256,
            side_max_steps=10_000, inject_tokens=8, theta=2.0,  # never merge mid-run
            sampling=SamplingParams(temperature=1.0),
        )
        eng.submit("benchmark prompt " + "[TASK: think] " * n_side, lane=0)
        for _ in range(3):
            eng.tick()  # warm both jit paths + spawn sides
        t0 = time.perf_counter()
        ticks = 15
        for _ in range(ticks):
            eng.tick()
        dt = (time.perf_counter() - t0) / ticks
        active_sides = sum(s.active for s in eng.sides)
        if base is None:
            base = dt
        emit(
            f"throughput.sides_{n_side}",
            dt * 1e6,
            f"active_sides={active_sides} slowdown={dt/base:.2f}x",
        )
        out[n_side] = {"tick_s": dt, "slowdown": dt / base, "active": active_sides}
    return out


if __name__ == "__main__":
    run()
