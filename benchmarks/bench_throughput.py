"""Paper §5.2 "Performance Characteristics": graceful degradation — main
agent step latency as side agents scale.

Post macro-tick engine: `run(n)` batches whole `sync_every` windows into
single scanned dispatches, so the host re-enters XLA once per window — the
numbers here amortize that dispatch over the window's virtual ticks. We
report measured wall time per virtual tick plus the engine's dispatch and
host-sync counters (`dispatches_per_tick` is the amortized 1/sync_every,
`ticks_per_dispatch` the window length) so the perf trajectory is auditable
across PRs.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams


def run(side_counts=(0, 2, 4, 8), ticks: int = 8, warmup: int = 16, sync_every: int = 8,
        reps: int = 12) -> dict:
    # best-of-reps over SINGLE-window chunks (timeit-style): the container
    # shares 2 cores with other processes and contention alternates on a
    # ~window timescale, so longer chunks always mix fast and slow windows;
    # the minimum over many one-window runs (each including its drain)
    # estimates the architecture's amortized latency, not the neighbors'
    # load. ticks defaults to one sync_every window per timed chunk.
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    out = {"sync_every": sync_every, "per_side": {}}
    base = None
    for n_side in side_counts:
        prism = Prism(params, cfg)
        eng = CortexEngine(
            prism, tok, n_main=1, max_side=max(n_side, 1), main_capacity=256,
            side_max_steps=10_000, inject_tokens=8, theta=2.0,  # never merge mid-run
            sampling=SamplingParams(temperature=1.0), sync_every=sync_every,
        )
        eng.submit("benchmark prompt " + "[TASK: think] " * n_side, lane=0)
        eng.run(warmup)  # warm the macro/fused-tick jits + spawn + drain paths
        stats0 = dict(eng.stats)
        dt, total = float("inf"), 0.0
        for _ in range(reps):
            t0 = time.perf_counter()
            eng.run(ticks)  # ceil(ticks/sync_every) dispatches, incl. drains
            jax.block_until_ready(eng.state.main_ring)
            rep_dt = (time.perf_counter() - t0) / ticks
            dt = min(dt, rep_dt)
            total += rep_dt
        active_sides = sum(s.active for s in eng.sides)
        dticks = eng.stats["ticks"] - stats0["ticks"]
        dispatches = eng.stats["tick_dispatches"] - stats0["tick_dispatches"]
        syncs = eng.stats["host_syncs"] - stats0["host_syncs"]
        if base is None:
            base = dt
        emit(
            f"throughput.sides_{n_side}",
            dt * 1e6,
            f"active_sides={active_sides} slowdown={dt/base:.2f}x mean={total/reps*1e6:.0f}us "
            f"dispatches/tick={dispatches/dticks:.3f} ticks/dispatch={dticks/dispatches:.1f} "
            f"syncs/tick={syncs/dticks:.3f}",
        )
        out["per_side"][n_side] = {
            "tick_s": dt,            # best-of-reps (noise-robust headline)
            "tick_s_mean": total / reps,  # mean incl. neighbor contention
            "slowdown": dt / base,
            "active": active_sides,
            "dispatches_per_tick": dispatches / dticks,
            "ticks_per_dispatch": dticks / dispatches,
            "macro_dispatches": eng.stats["macro_dispatches"] - stats0["macro_dispatches"],
            "host_syncs_per_tick": syncs / dticks,
        }
    return out


if __name__ == "__main__":
    run()
