"""Paper §5.2 "Performance Characteristics": graceful degradation — main
agent step latency as side agents scale.

Post macro-tick engine: `run(n)` batches whole `sync_every` windows into
single scanned dispatches, so the host re-enters XLA once per window — the
numbers here amortize that dispatch over the window's virtual ticks. Since
the pipelined-drain engine (ISSUE 5), each window's host post-processing
(router scan, detokenize, bookkeeping) overlaps the device's next window
whenever the drain gate proves the window control-free; `overlap_fraction`
records how often that happened and `window_hist` the dispatched window
lengths. Three sections:

* ``per_side`` — the PR 4 protocol unchanged (pinned ``sync_every`` window,
  best-of-reps over single-window chunks) so `tick_s` stays comparable
  across PRs, now with overlap/window telemetry;
* ``ab`` — serial (PR 4 lockstep) vs pipelined drains, interleaved reps on
  the same protocol at the largest side count: the architectural win of
  overlapping host control with device compute;
* ``adaptive`` — a trigger-free greedy run with ``max_window`` adaptation:
  the window histogram must show windows actually lengthening (the ladder
  climbing to ``max_window``) and a dispatch rate below 1/sync_every.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams


def _engine(params, cfg, tok, *, n_side, sync_every, pipeline=True,
            max_window=None, sampling=SamplingParams(temperature=1.0)):
    prism = Prism(params, cfg)
    return CortexEngine(
        prism, tok, n_main=1, max_side=max(n_side, 1), main_capacity=256,
        side_max_steps=10_000, inject_tokens=8, theta=2.0,  # never merge mid-run
        sampling=sampling, sync_every=sync_every,
        pipeline=pipeline, max_window=max_window,
    )


def run(side_counts=(0, 2, 4, 8), ticks: int = 8, warmup: int = 16, sync_every: int = 8,
        reps: int = 12, ab_reps: int = 8, adaptive_ticks: int = 128,
        max_window: int | None = None) -> dict:
    # best-of-reps over SINGLE-window chunks (timeit-style): the container
    # shares 2 cores with other processes and contention alternates on a
    # ~window timescale, so longer chunks always mix fast and slow windows;
    # the minimum over many one-window runs (each including its drain)
    # estimates the architecture's amortized latency, not the neighbors'
    # load. ticks defaults to one sync_every window per timed chunk.
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    max_window = max_window or 4 * sync_every
    out = {"sync_every": sync_every, "per_side": {}}
    base = None
    for n_side in side_counts:
        eng = _engine(params, cfg, tok, n_side=n_side, sync_every=sync_every)
        eng.submit("benchmark prompt " + "[TASK: think] " * n_side, lane=0)
        eng.run(warmup)  # warm the macro/fused-tick jits + spawn + drain paths
        stats0 = dict(eng.stats)
        dt, total = float("inf"), 0.0
        for _ in range(reps):
            t0 = time.perf_counter()
            eng.run(ticks)  # ceil(ticks/sync_every) dispatches, incl. drains
            jax.block_until_ready(eng.state.main_ring)
            rep_dt = (time.perf_counter() - t0) / ticks
            dt = min(dt, rep_dt)
            total += rep_dt
        active_sides = sum(s.active for s in eng.sides)
        dticks = eng.stats["ticks"] - stats0["ticks"]
        dispatches = eng.stats["tick_dispatches"] - stats0["tick_dispatches"]
        syncs = eng.stats["host_syncs"] - stats0["host_syncs"]
        drains = eng.stats["drains"] - stats0["drains"]
        overlapped = eng.stats["overlapped_drains"] - stats0["overlapped_drains"]
        if base is None:
            base = dt
        emit(
            f"throughput.sides_{n_side}",
            dt * 1e6,
            f"active_sides={active_sides} slowdown={dt/base:.2f}x mean={total/reps*1e6:.0f}us "
            f"dispatches/tick={dispatches/dticks:.3f} ticks/dispatch={dticks/dispatches:.1f} "
            f"syncs/tick={syncs/dticks:.3f} overlap={overlapped/max(drains,1):.2f}",
        )
        out["per_side"][n_side] = {
            "tick_s": dt,            # best-of-reps (noise-robust headline)
            "tick_s_mean": total / reps,  # mean incl. neighbor contention
            "slowdown": dt / base,
            "active": active_sides,
            "dispatches_per_tick": dispatches / dticks,
            "ticks_per_dispatch": dticks / dispatches,
            "macro_dispatches": eng.stats["macro_dispatches"] - stats0["macro_dispatches"],
            "host_syncs_per_tick": syncs / dticks,
            # pipelined-drain telemetry: fraction of drains whose host work
            # overlapped the next window's device execution
            "overlap_fraction": overlapped / max(drains, 1),
            "window_hist": dict(eng.stats["window_hist"]),
        }
    out["ab"] = _ab_serial_vs_pipelined(
        params, cfg, tok, n_side=max(side_counts), sync_every=sync_every,
        ticks=ticks, warmup=warmup, reps=ab_reps,
    )
    out["adaptive"] = _adaptive_trigger_free(
        params, cfg, tok, sync_every=sync_every, max_window=max_window,
        n_ticks=adaptive_ticks,
    )
    return out


def _ab_serial_vs_pipelined(params, cfg, tok, *, n_side, sync_every, ticks,
                            warmup, reps) -> dict:
    """Matched-protocol interleaved A/B: the SAME workload on the serial
    PR 4 loop vs the pipelined drain, reps alternating so neighbor
    contention hits both arms equally. Streams are bitwise identical
    (asserted) — only the host/device overlap differs."""
    # multi-window chunks: the pipeline overlaps host work for window t
    # with device window t+1, so a chunk must span several windows for the
    # overlap to exist at all (a single-window chunk is drained serially)
    chunk = 4 * ticks
    engines = {}
    for mode, pipeline in (("serial", False), ("pipelined", True)):
        eng = _engine(params, cfg, tok, n_side=n_side, sync_every=sync_every,
                      pipeline=pipeline)
        eng.submit("benchmark prompt " + "[TASK: think] " * n_side, lane=0)
        eng.run(warmup)
        engines[mode] = eng
    best = {mode: float("inf") for mode in engines}
    for _ in range(reps):
        for mode, eng in engines.items():
            t0 = time.perf_counter()
            eng.run(chunk)
            jax.block_until_ready(eng.state.main_ring)
            best[mode] = min(best[mode], (time.perf_counter() - t0) / chunk)
    # the pipeline reorders host work only: parity is part of the protocol
    assert engines["serial"].mains[0].tokens == engines["pipelined"].mains[0].tokens
    res = {
        "serial_tick_s": best["serial"],
        "pipelined_tick_s": best["pipelined"],
        "speedup": best["serial"] / best["pipelined"],
        "overlap_fraction": (
            engines["pipelined"].stats["overlapped_drains"]
            / max(engines["pipelined"].stats["drains"], 1)
        ),
    }
    emit(
        "throughput.ab_pipelined",
        best["pipelined"] * 1e6,
        f"serial={best['serial']*1e6:.0f}us speedup={res['speedup']:.2f}x "
        f"overlap={res['overlap_fraction']:.2f}",
    )
    return res


def _adaptive_trigger_free(params, cfg, tok, *, sync_every, max_window,
                           n_ticks) -> dict:
    """Greedy, tag-free run with adaptation on: quiet drains climb the
    window ladder, so the histogram must show windows longer than the base
    and the amortized dispatch rate must drop below 1/sync_every."""
    eng = _engine(params, cfg, tok, n_side=0, sync_every=sync_every,
                  max_window=max_window, sampling=SamplingParams(greedy=True))
    eng.submit("calm benchmark prose without any control tags", lane=0)
    # warm until the TOP rung has actually been dispatched (the policy
    # climbs one drain behind the pipelined dispatch, so a single ladder
    # walk would leave the max_window scan uncompiled and the first timed
    # rep would pay its jit)
    for _ in range(4):
        eng.run(2 * eng.max_window)
        if eng.stats["window_hist"].get(eng.max_window):
            break
    stats0 = dict(eng.stats)
    hist0 = dict(eng.stats["window_hist"])
    # best-of-reps like the headline numbers: chunks of two max windows
    # (the policy stays on the top rung while drains remain quiet)
    chunk = 2 * eng.max_window
    tick_s = float("inf")
    for _ in range(max(1, n_ticks // chunk)):
        t0 = time.perf_counter()
        eng.run(chunk)
        jax.block_until_ready(eng.state.main_ring)
        tick_s = min(tick_s, (time.perf_counter() - t0) / chunk)
    dticks = eng.stats["ticks"] - stats0["ticks"]
    dispatches = eng.stats["tick_dispatches"] - stats0["tick_dispatches"]
    drains = eng.stats["drains"] - stats0["drains"]
    overlapped = eng.stats["overlapped_drains"] - stats0["overlapped_drains"]
    hist = {
        w: c - hist0.get(w, 0)
        for w, c in eng.stats["window_hist"].items()
        if c - hist0.get(w, 0)
    }
    res = {
        "tick_s": tick_s,
        "base_window": sync_every,
        "max_window": eng.max_window,
        "ticks": dticks,
        "window_hist": hist,
        "longest_window": max(hist),
        "dispatches_per_tick": dispatches / dticks,
        "overlap_fraction": overlapped / max(drains, 1),
    }
    emit(
        "throughput.adaptive",
        res["tick_s"] * 1e6,
        f"window_hist={hist} dispatches/tick={res['dispatches_per_tick']:.3f} "
        f"overlap={res['overlap_fraction']:.2f}",
    )
    return res


if __name__ == "__main__":
    run()
