"""Paper §5.2 "Performance Characteristics": graceful degradation — main
agent step latency as side agents scale.

Post fused-tick engine: each tick is ONE jitted dispatch with donated
caches; sampled tokens drain to the host every `sync_every` ticks. The
numbers here are therefore dispatch-bound no longer — side agents ride the
same fused step and the dominant cost is the (tiny, CPU-emulated) model
itself. We report measured wall time per tick plus the engine's dispatch
and host-sync counters so the perf trajectory is auditable across PRs.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams


def run(side_counts=(0, 2, 4, 8), ticks: int = 16, warmup: int = 16, sync_every: int = 8) -> dict:
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    out = {"sync_every": sync_every, "per_side": {}}
    base = None
    for n_side in side_counts:
        prism = Prism(params, cfg)
        eng = CortexEngine(
            prism, tok, n_main=1, max_side=max(n_side, 1), main_capacity=256,
            side_max_steps=10_000, inject_tokens=8, theta=2.0,  # never merge mid-run
            sampling=SamplingParams(temperature=1.0), sync_every=sync_every,
        )
        eng.submit("benchmark prompt " + "[TASK: think] " * n_side, lane=0)
        for _ in range(warmup):
            eng.tick()  # warm the fused-tick jits + spawn sides + drain paths
        stats0 = dict(eng.stats)
        t0 = time.perf_counter()
        for _ in range(ticks):
            eng.tick()
        jax.block_until_ready(eng.state.main_ring)
        dt = (time.perf_counter() - t0) / ticks
        active_sides = sum(s.active for s in eng.sides)
        dispatches = eng.stats["tick_dispatches"] - stats0["tick_dispatches"]
        syncs = eng.stats["host_syncs"] - stats0["host_syncs"]
        if base is None:
            base = dt
        emit(
            f"throughput.sides_{n_side}",
            dt * 1e6,
            f"active_sides={active_sides} slowdown={dt/base:.2f}x "
            f"dispatches/tick={dispatches/ticks:.2f} syncs/tick={syncs/ticks:.2f}",
        )
        out["per_side"][n_side] = {
            "tick_s": dt,
            "slowdown": dt / base,
            "active": active_sides,
            "dispatches_per_tick": dispatches / ticks,
            "host_syncs_per_tick": syncs / ticks,
        }
    return out


if __name__ == "__main__":
    run()
