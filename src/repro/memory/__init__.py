"""Tiered synapse memory: hot (device lane) / warm (host RAM) / cold (disk).

`SynapseStore` holds hibernated agents' cache snapshots; `AgentRegistry`
owns agent identity independent of lane slots, so engines and servers can
register far more agents than they have live lanes. The cold tier is
integrity-checked and crash-recoverable (see `store` and `checkpoint.io`);
`faults.FaultInjector` drives the resilience test suite.
"""
from .faults import FaultInjector, WorkerKill
from .registry import (
    ACTIVE,
    HIBERNATED,
    LOST,
    REGISTERED,
    AgentRecord,
    AgentRegistry,
)
from .store import (
    COLD,
    WARM,
    SnapshotLostError,
    SynapseStore,
    WakeTicket,
    WorkerDiedError,
)

__all__ = [
    "AgentRecord",
    "AgentRegistry",
    "FaultInjector",
    "SnapshotLostError",
    "SynapseStore",
    "WakeTicket",
    "WorkerDiedError",
    "WorkerKill",
    "ACTIVE",
    "HIBERNATED",
    "LOST",
    "REGISTERED",
    "WARM",
    "COLD",
]
