"""Tiered synapse memory: hot (device lane) / warm (host RAM) / cold (disk).

`SynapseStore` holds hibernated agents' cache snapshots; `AgentRegistry`
owns agent identity independent of lane slots, so engines and servers can
register far more agents than they have live lanes.
"""
from .registry import ACTIVE, HIBERNATED, REGISTERED, AgentRecord, AgentRegistry
from .store import COLD, WARM, SynapseStore, WakeTicket

__all__ = [
    "AgentRecord",
    "AgentRegistry",
    "SynapseStore",
    "WakeTicket",
    "ACTIVE",
    "HIBERNATED",
    "REGISTERED",
    "WARM",
    "COLD",
]
