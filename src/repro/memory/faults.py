"""Deterministic fault injection for the tiered synapse memory (ISSUE 8).

The `SynapseStore` exposes three I/O boundaries where real systems break:
the cold **write** (torn by a crash mid-`write()`), the cold **read**
(flipped bits from bad media, transient ``OSError`` from a flaky mount),
and the worker-thread **promotion** (a slow/blocked ``device_put``, or the
thread dying outright). A :class:`FaultInjector` attached via
``SynapseStore(faults=...)`` (or ``store.faults = ...``) fires scripted
faults at exactly those boundaries — and nowhere else, so the injected
failure modes are the ones production code actually has to survive.

Everything is deterministic: rules fire on the Nth *matching* call (per
rule counter), never on wall-clock or RNG state, so a failing resilience
test replays exactly. Every fired fault is recorded in ``events`` and
summarized by :meth:`report` — the chaos smoke uploads that as the CI
fault-injection artifact.

Rule matching: ``key`` is an exact agent key or ``"*"``; ``nth`` is
1-based over matching calls; ``times`` repeats the fault for that many
consecutive matching calls (so ``nth=1, times=2`` = "fail the first two
reads" — exercising retry-until-success).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


class WorkerKill(BaseException):
    """Raised inside the prefetch worker to simulate the thread dying.

    Deliberately a ``BaseException``: the store's worker loop (correctly)
    catches only ``Exception``, so this escapes, kills the thread, and
    exercises the `heal_worker` supervision path end to end."""


@dataclass
class FaultEvent:
    op: str      # "cold_write" | "cold_read" | "put_fn"
    key: str
    fault: str   # "torn_write" | "flip" | "fail_read" | "slow_put" | "kill_worker"
    call: int    # which matching call fired (1-based)
    detail: str = ""


@dataclass
class _Rule:
    op: str
    key: str          # exact key or "*"
    fault: str
    nth: int          # fire on the nth matching call...
    times: int        # ...and for this many consecutive matches
    params: Dict[str, Any] = field(default_factory=dict)
    seen: int = 0     # matching calls observed so far

    def matches(self, key: str) -> bool:
        return self.key == "*" or self.key == key

    def should_fire(self) -> bool:
        # called with seen already incremented for this call
        return self.nth <= self.seen < self.nth + self.times


class FaultInjector:
    """Scripted, deterministic faults at the store's I/O boundaries."""

    def __init__(self) -> None:
        self._rules: List[_Rule] = []
        self._lock = threading.Lock()
        self.events: List[FaultEvent] = []

    # -- rule registration (chainable) ------------------------------------
    def _add(self, op: str, key: str, fault: str, nth: int, times: int,
             **params) -> "FaultInjector":
        if nth < 1 or times < 1:
            raise ValueError("nth and times are 1-based counts")
        self._rules.append(_Rule(op, key, fault, nth, times, params))
        return self

    def torn_write(self, key: str = "*", *, frac: float = 0.5,
                   nth: int = 1, times: int = 1) -> "FaultInjector":
        """Truncate the blob to ``frac`` of its bytes before it hits disk —
        what a crash mid-write leaves behind (the atomic rename still
        happens, as it would if power died just after)."""
        return self._add("cold_write", key, "torn_write", nth, times, frac=frac)

    def flip_write(self, key: str = "*", *, offset: Optional[int] = None,
                   nth: int = 1, times: int = 1) -> "FaultInjector":
        """XOR one byte of the blob on its way to disk (silent media
        corruption). ``offset`` indexes into the payload region by default
        (past the header+meta, so the digest — not the header parse —
        catches it); negative offsets index from the end."""
        return self._add("cold_write", key, "flip", nth, times, offset=offset)

    def fail_read(self, key: str = "*", *, nth: int = 1, times: int = 1,
                  error: type = OSError) -> "FaultInjector":
        """Raise ``error`` on the nth..nth+times-1 matching cold reads —
        ``OSError`` (default) is what the store treats as transient and
        retries; pass a different type to test permanent-failure paths."""
        return self._add("cold_read", key, "fail_read", nth, times, error=error)

    def flip_read(self, key: str = "*", *, offset: Optional[int] = None,
                  nth: int = 1, times: int = 1) -> "FaultInjector":
        """XOR one byte of the blob as it is read back (bad sector)."""
        return self._add("cold_read", key, "flip", nth, times, offset=offset)

    def truncate_read(self, key: str = "*", *, frac: float = 0.5,
                      nth: int = 1, times: int = 1) -> "FaultInjector":
        """Return only the first ``frac`` of the blob's bytes (short read)."""
        return self._add("cold_read", key, "torn_write", nth, times, frac=frac)

    def kill_worker_on_read(self, key: str = "*", *, nth: int = 1,
                            times: int = 1) -> "FaultInjector":
        """Raise :class:`WorkerKill` (a BaseException) from the read hook:
        kills the prefetch thread dead, in-flight ticket and all."""
        return self._add("cold_read", key, "kill_worker", nth, times)

    def slow_put(self, key: str = "*", *, seconds: float,
                 nth: int = 1, times: int = 1) -> "FaultInjector":
        """Sleep inside the worker just before ``put_fn`` — a stalled
        host->device copy. Pair with a wake deadline to test host-side
        expiry of a blocked promotion."""
        return self._add("put_fn", key, "slow_put", nth, times, seconds=seconds)

    def block_put(self, key: str = "*", *, release: threading.Event,
                  timeout: float = 30.0, nth: int = 1,
                  times: int = 1) -> "FaultInjector":
        """Block ``put_fn`` until the test sets ``release`` (bounded by
        ``timeout`` so a buggy test can't hang the suite)."""
        return self._add("put_fn", key, "block_put", nth, times,
                         release=release, timeout=timeout)

    # -- hooks called by SynapseStore -------------------------------------
    def _fire(self, op: str, key: str) -> List[_Rule]:
        with self._lock:
            fired = []
            for rule in self._rules:
                if rule.op != op or not rule.matches(key):
                    continue
                rule.seen += 1
                if rule.should_fire():
                    fired.append(rule)
                    self.events.append(FaultEvent(
                        op, key, rule.fault, rule.seen,
                        detail=str({k: v for k, v in rule.params.items()
                                    if not isinstance(v, threading.Event)}),
                    ))
            return fired

    @staticmethod
    def _mangle(data: bytes, rule: _Rule) -> bytes:
        if rule.fault == "torn_write":
            return data[: max(1, int(len(data) * rule.params["frac"]))]
        if rule.fault == "flip":
            offset = rule.params.get("offset")
            # default: flip a byte well into the blob — inside the payload
            # region for any realistic frame, so the digest check (not the
            # header parse) is what must catch it
            i = (len(data) - 8) if offset is None else offset
            i = i % len(data)
            return data[:i] + bytes([data[i] ^ 0x80]) + data[i + 1:]
        return data

    def on_cold_write(self, key: str, blob: bytes) -> bytes:
        for rule in self._fire("cold_write", key):
            blob = self._mangle(blob, rule)
        return blob

    def on_cold_read(self, key: str, data: bytes) -> bytes:
        for rule in self._fire("cold_read", key):
            if rule.fault == "fail_read":
                raise rule.params["error"](f"injected read failure for {key!r}")
            if rule.fault == "kill_worker":
                raise WorkerKill(f"injected worker death reading {key!r}")
            data = self._mangle(data, rule)
        return data

    def on_put_fn(self, key: str) -> None:
        for rule in self._fire("put_fn", key):
            if rule.fault == "slow_put":
                time.sleep(rule.params["seconds"])
            elif rule.fault == "block_put":
                rule.params["release"].wait(rule.params["timeout"])

    # -- reporting --------------------------------------------------------
    def report(self) -> dict:
        """Summary for test assertions and the CI chaos artifact."""
        with self._lock:
            by_fault: Dict[str, int] = {}
            for ev in self.events:
                by_fault[ev.fault] = by_fault.get(ev.fault, 0) + 1
            return {
                "events": [
                    {"op": e.op, "key": e.key, "fault": e.fault,
                     "call": e.call, "detail": e.detail}
                    for e in self.events
                ],
                "fired_total": len(self.events),
                "fired_by_fault": by_fault,
                "rules": len(self._rules),
            }
