"""Agent identity, decoupled from lane slots.

Historically a `CortexEngine` agent *was* its lane: ``mains[i]`` held the
one AgentView that would ever live in lane ``i``. The registry breaks that
identification so an agent can exist without holding a lane (hibernated in
the warm/cold tiers of the `SynapseStore`) and can wake into *any* free
lane. Greedy decoding only depends on a lane's own cache/token/position
state, so the slot an agent wakes into is immaterial to its token stream.

Only identity and host-side bookkeeping live here (the AgentView, its
sampling params, router tails stay keyed by agent_id in the engine's
router). Device state for non-active agents lives in the SynapseStore.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional

# status values
REGISTERED = "registered"  # known, but holds no context (never ran / overwritten)
ACTIVE = "active"          # bound to a live lane on device
HIBERNATED = "hibernated"  # context parked in the SynapseStore (warm/cold)
LOST = "lost"              # context permanently unrecoverable (corrupt/missing blob)


@dataclass
class AgentRecord:
    agent_id: str
    kind: str = "main"          # "main" | "side" | "request"
    status: str = REGISTERED
    lane: int = -1              # valid only while ACTIVE
    last_event: int = 0         # monotonic clock of last submit/wake/bind — LRU key
    bound_tick: int = 0         # engine tick at last bind — idle-ticks policy input
    saved: Any = None           # host bookkeeping while HIBERNATED (view, sampling, ...)


class AgentRegistry:
    """Owns agent_id -> AgentRecord; provides LRU queries for eviction."""

    def __init__(self) -> None:
        self._records: Dict[str, AgentRecord] = {}
        self._clock = 0

    # -- clock ------------------------------------------------------------
    def tick(self) -> int:
        """Advance and return the registry's monotonic event clock."""
        self._clock += 1
        return self._clock

    # -- crud -------------------------------------------------------------
    def register(self, agent_id: str, kind: str = "main") -> AgentRecord:
        rec = self._records.get(agent_id)
        if rec is None:
            rec = AgentRecord(agent_id=agent_id, kind=kind, last_event=self.tick())
            self._records[agent_id] = rec
        return rec

    def get(self, agent_id: str) -> AgentRecord:
        return self._records[agent_id]

    def __contains__(self, agent_id: str) -> bool:
        return agent_id in self._records

    def forget(self, agent_id: str) -> None:
        self._records.pop(agent_id, None)

    # -- state transitions ------------------------------------------------
    def bind(self, agent_id: str, lane: int) -> AgentRecord:
        rec = self._records[agent_id]
        rec.status, rec.lane, rec.saved = ACTIVE, lane, None
        rec.last_event = self.tick()
        return rec

    def hibernate(self, agent_id: str, saved: Any) -> AgentRecord:
        rec = self._records[agent_id]
        rec.status, rec.lane, rec.saved = HIBERNATED, -1, saved
        rec.last_event = self.tick()
        return rec

    def release(self, agent_id: str) -> None:
        """Agent lost its context (overwritten / merged / retired)."""
        rec = self._records.get(agent_id)
        if rec is not None:
            rec.status, rec.lane, rec.saved = REGISTERED, -1, None

    def mark_lost(self, agent_id: str) -> Optional[AgentRecord]:
        """Terminal degradation: the agent's parked context is permanently
        unrecoverable (quarantined blob, vanished file). Identity is kept —
        callers can observe what was lost and why — but the record holds no
        lane and no saved state; only a fresh ``submit`` revives the id."""
        rec = self._records.get(agent_id)
        if rec is not None:
            rec.status, rec.lane, rec.saved = LOST, -1, None
            rec.last_event = self.tick()
        return rec

    # -- queries ----------------------------------------------------------
    def with_status(self, status: str, kind: Optional[str] = None) -> List[AgentRecord]:
        return [
            r
            for r in self._records.values()
            if r.status == status and (kind is None or r.kind == kind)
        ]

    def agent_at(self, lane: int, kind: str) -> Optional[AgentRecord]:
        for r in self._records.values():
            if r.status == ACTIVE and r.kind == kind and r.lane == lane:
                return r
        return None

    def lru_active(
        self, kind: Optional[str] = None, *, exclude: Iterable[str] = ()
    ) -> Optional[AgentRecord]:
        """Least-recently-touched ACTIVE record — the eviction candidate."""
        skip = set(exclude)
        cands = [r for r in self.with_status(ACTIVE, kind) if r.agent_id not in skip]
        return min(cands, key=lambda r: r.last_event) if cands else None

    def counts(self) -> Dict[str, int]:
        by = {REGISTERED: 0, ACTIVE: 0, HIBERNATED: 0, LOST: 0}
        for r in self._records.values():
            by[r.status] += 1
        total = len(self._records)
        return {
            "registered": total,
            "active": by[ACTIVE],
            "hibernated": by[HIBERNATED],
            "lost": by[LOST],
            "dormant": total - by[ACTIVE],
        }
