"""SynapseStore: the warm/cold tiers of the agent-memory hierarchy.

Tiers (paper §"million-agent capacity"; cache-hierarchy treatment per
"Multi-Agent Memory from a Computer Architecture Perspective"):

* **hot**  — a live lane inside the engine's `TickState` on device. Not
  stored here; the store only sees agents once they leave the device.
* **warm** — host RAM: the agent's landmark-compressed cache slice plus
  per-lane scalars, as a numpy pytree (exact device bytes, no re-encode).
* **cold** — disk: the same pytree through the `checkpoint/io` FRAMED codec
  (magic + version + checksummed zstd/zlib payload), one blob per agent;
  only a ShapeDtypeStruct skeleton stays in RAM so a million cold agents
  cost ~nothing on the host.

Demotion warm→cold is LRU, triggered when `warm_capacity_bytes` is
exceeded (and on explicit `demote()`); without a `cold_dir` entries simply
stay warm and the skip is counted in the report rather than raised mid-run.
(`zstandard` is optional: the framed codec falls back to stdlib zlib.)

Promotion is asynchronous: `prefetch()` hands back a `WakeTicket` and a
daemon worker thread reads the blob / host pytree and (optionally) lands
it on device via the caller's `put_fn` (e.g. `jax.device_put` with the
replicated sharding). `transfer_guard` contexts are thread-local in JAX,
so the worker's explicit transfers never trip the engine's "no transfers
in the overlap region" invariant — the engine only *commits* the already
device-resident buffers at a window boundary.

Resilience contract (ISSUE 8) — the hierarchy must degrade, never crash:

* every cold read verifies the frame checksum; a corrupt/truncated blob is
  moved into ``cold_dir/quarantine/`` and surfaces as a typed
  :class:`SnapshotLostError` (a ``KeyError`` subclass), never a raw codec
  exception mid-wake;
* the cold index is mirrored in an atomic on-disk manifest and every blob
  embeds its own key/skeleton/bookkeeping in the frame metadata, so
  :meth:`recover` rebuilds the tier — skeletons included — after a process
  restart (manifest-first, then orphan blobs from a crash mid-demotion);
* `prefetch()` retries transient I/O (``OSError``) with bounded
  exponential backoff; tickets carry an optional deadline and a terminal
  *failed* state; :meth:`heal_worker` detects a dead worker thread, fails
  its in-flight ticket (instead of hanging the waiter forever) and
  respawns the thread;
* a :class:`repro.memory.faults.FaultInjector` can be attached (``faults=``)
  to deterministically inject torn writes, bit flips, failed reads, slow
  ``put_fn`` and worker death at the exact I/O boundaries production code
  uses — the resilience suite and the chaos smoke drive it.

Snapshots are stored bitwise: a wake must reproduce the exact greedy
stream of a lane that never hibernated, so nothing here may re-quantize.
"""
from __future__ import annotations

import os
import pickle
import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import io as ckpt_io
from ..core.prism import tree_bytes

WARM = "warm"
COLD = "cold"

BLOB_SUFFIX = ".synapse.blob"
MANIFEST_NAME = "MANIFEST.pkl"
QUARANTINE_DIR = "quarantine"
MANIFEST_VERSION = 1


class SnapshotLostError(KeyError):
    """A snapshot that the index believed existed is permanently gone
    (corrupt/truncated blob quarantined, or its file vanished while still
    indexed). Subclasses ``KeyError`` so legacy callers that treated every
    miss as a key error keep working; new callers can tell loss (was there,
    now unrecoverable) from a plain miss (never there / already dropped)."""

    def __str__(self) -> str:  # KeyError quotes its arg; keep messages readable
        return ": ".join(str(a) for a in self.args)


class WorkerDiedError(RuntimeError):
    """The prefetch worker thread died while this ticket was in flight."""


def _host_tree(tree):
    """Materialize any (device or host) pytree as numpy leaves."""
    return jax.tree_util.tree_map(np.asarray, jax.device_get(tree))


def _skeleton(tree):
    """Shape/dtype-only skeleton — what stays in RAM for a cold agent."""
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), tree
    )


@dataclass
class ColdEntry:
    """RAM-side record of one cold blob (the blob itself is on disk)."""

    path: str
    skeleton: Any          # ShapeDtypeStruct pytree (decode template)
    comp_bytes: int        # framed file size on disk
    raw_bytes: int         # uncompressed snapshot bytes (accounting)
    meta: Optional[dict] = None  # caller bookkeeping (engine: view/sampling)


class WakeTicket:
    """Handle for an in-flight asynchronous promotion (wake prefetch).

    Terminal states are *ready* (``result()`` returns the value) and
    *failed* (``result()`` raises the stored error). Transitions are
    first-wins: a worker resolving a ticket the host already expired — or
    vice versa — is a no-op, so a blocked worker can be abandoned safely
    and finish into the void."""

    def __init__(self, key: str, *, deadline: Optional[float] = None):
        self.key = key
        self.deadline = deadline  # absolute time.monotonic() timestamp
        self._done = threading.Event()
        self._lock = threading.Lock()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    def _resolve(self, value: Any) -> bool:
        with self._lock:
            if self._done.is_set():
                return False
            self._value = value
            self._done.set()
            return True

    def _fail(self, err: BaseException) -> bool:
        with self._lock:
            if self._done.is_set():
                return False
            self._error = err
            self._done.set()
            return True

    # -- state queries -----------------------------------------------------
    def ready(self) -> bool:
        """Terminal (resolved OR failed) — 'nothing left to wait for'."""
        return self._done.is_set()

    def failed(self) -> bool:
        return self._done.is_set() and self._error is not None

    @property
    def error(self) -> Optional[BaseException]:
        return self._error

    @property
    def state(self) -> str:
        if not self._done.is_set():
            return "pending"
        return "failed" if self._error is not None else "ready"

    # -- deadlines ---------------------------------------------------------
    def remaining(self, now: Optional[float] = None) -> Optional[float]:
        if self.deadline is None:
            return None
        return max(0.0, self.deadline - (time.monotonic() if now is None else now))

    def expired(self, now: Optional[float] = None) -> bool:
        return (
            self.deadline is not None
            and (time.monotonic() if now is None else now) >= self.deadline
        )

    def expire(self, now: Optional[float] = None) -> bool:
        """Host-side deadline enforcement: fail the ticket if its deadline
        passed and no terminal state was reached (e.g. the worker is stuck
        in a blocked ``put_fn``). Returns True if THIS call failed it."""
        if not self.expired(now) or self._done.is_set():
            return False
        return self._fail(
            TimeoutError(f"wake deadline exceeded for {self.key!r}")
        )

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._done.wait(timeout):
            raise TimeoutError(f"wake prefetch for {self.key!r} still in flight")
        if self._error is not None:
            raise self._error
        return self._value


class SynapseStore:
    """Warm (host RAM) + cold (framed disk) storage for hibernated agents."""

    def __init__(
        self,
        *,
        warm_capacity_bytes: Optional[int] = None,
        cold_dir: Optional[str] = None,
        cold_level: int = 3,
        wake_retries: int = 3,
        wake_backoff_s: float = 0.02,
        wake_backoff_cap_s: float = 1.0,
        faults=None,
    ):
        self.warm_capacity_bytes = warm_capacity_bytes
        self.cold_dir = cold_dir
        self.cold_level = cold_level
        self.wake_retries = wake_retries
        self.wake_backoff_s = wake_backoff_s
        self.wake_backoff_cap_s = wake_backoff_cap_s
        self.faults = faults  # FaultInjector | None — test/chaos hook
        self._lock = threading.RLock()
        # key -> numpy pytree; insertion order doubles as LRU order
        self._warm: Dict[str, Any] = {}
        self._warm_bytes: Dict[str, int] = {}
        self._warm_meta: Dict[str, Optional[dict]] = {}
        self._cold: Dict[str, ColdEntry] = {}
        self.stats = {
            "puts": 0,
            "demotions": 0,
            "demotions_skipped": 0,
            "prefetches": 0,
            "cold_reads": 0,
            # resilience telemetry (ISSUE 8)
            "quarantined": 0,      # corrupt/truncated blobs moved aside
            "lost": 0,             # indexed snapshots found unrecoverable
            "wake_retries": 0,     # transient read failures retried
            "prefetch_errors": 0,  # tickets that ended in the failed state
            "worker_respawns": 0,  # dead prefetch threads resurrected
            "recovered": 0,        # cold entries rebuilt by recover()
        }
        self._work: "queue.SimpleQueue" = queue.SimpleQueue()
        self._worker: Optional[threading.Thread] = None
        self._inflight: Optional[WakeTicket] = None  # ticket the worker holds

    # -- tier plumbing ----------------------------------------------------
    @property
    def cold_enabled(self) -> bool:
        # the framed codec falls back to zlib, so a cold_dir alone is enough
        return self.cold_dir is not None

    def _cold_path(self, key: str) -> str:
        import zlib as _zlib

        safe = "".join(c if (c.isalnum() or c in "._-") else "_" for c in key)
        # the crc suffix keeps two keys that mangle identically ("a b" vs
        # "a_b") from silently sharing one blob file
        tag = _zlib.crc32(key.encode()) & 0xFFFFFFFF
        return os.path.join(self.cold_dir, f"{safe}-{tag:08x}{BLOB_SUFFIX}")

    def quarantine_dir(self) -> Optional[str]:
        if self.cold_dir is None:
            return None
        return os.path.join(self.cold_dir, QUARANTINE_DIR)

    def warm_bytes(self) -> int:
        with self._lock:
            return sum(self._warm_bytes.values())

    def keys(self):
        with self._lock:
            return list(self._warm) + list(self._cold)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._warm or key in self._cold

    def tier_of(self, key: str) -> Optional[str]:
        with self._lock:
            if key in self._warm:
                return WARM
            if key in self._cold:
                return COLD
            return None

    def meta_of(self, key: str) -> Optional[dict]:
        """Caller bookkeeping attached at put() time (survives demotion and
        :meth:`recover` — it rides the blob's frame metadata)."""
        with self._lock:
            if key in self._warm:
                return self._warm_meta.get(key)
            entry = self._cold.get(key)
            return entry.meta if entry is not None else None

    # -- demotion (device -> warm -> cold) --------------------------------
    def put(self, key: str, tree, meta: Optional[dict] = None) -> None:
        """Park a snapshot in the warm tier (demoting LRU entries to cold
        if over capacity). `tree` may hold device or numpy leaves. ``meta``
        is small picklable bookkeeping (agent kind/view/sampling) persisted
        with the blob so a crashed process can re-adopt the agent."""
        host = _host_tree(tree)
        with self._lock:
            stale = self._cold.pop(key, None)
            self._warm.pop(key, None)  # re-put refreshes LRU position
            self._warm[key] = host
            self._warm_bytes[key] = tree_bytes(host)
            self._warm_meta[key] = meta
            self.stats["puts"] += 1
            if stale is not None:
                # superseded cold blob must not leak on disk. Unlinked under
                # the lock: demotion recreates the SAME path, so an unlocked
                # stale unlink could race a concurrent re-demotion and delete
                # the fresh blob out from under the index.
                try:
                    os.remove(stale.path)
                except OSError:
                    pass
                self._write_manifest_locked()
            self._enforce_capacity_locked()

    def _enforce_capacity_locked(self) -> None:
        if self.warm_capacity_bytes is None:
            return
        while sum(self._warm_bytes.values()) > self.warm_capacity_bytes and self._warm:
            oldest = next(iter(self._warm))
            if not self._demote_locked(oldest):
                self.stats["demotions_skipped"] += 1
                break  # no cold backing: stay warm rather than drop state

    def demote(self, key: str) -> bool:
        """Explicitly push one warm entry to the cold tier."""
        with self._lock:
            return self._demote_locked(key)

    def demote_lru(self) -> Optional[str]:
        with self._lock:
            if not self._warm:
                return None
            oldest = next(iter(self._warm))
            return oldest if self._demote_locked(oldest) else None

    def _demote_locked(self, key: str) -> bool:
        if key not in self._warm or not self.cold_enabled:
            return False
        host = self._warm[key]
        raw = self._warm_bytes[key]
        meta = self._warm_meta.get(key)
        skel = _skeleton(host)
        # the blob is self-describing: key + skeleton + bookkeeping ride the
        # checksummed frame metadata, so recover() can re-adopt an orphan
        # blob whose manifest entry never landed (crash mid-demotion)
        frame_meta = pickle.dumps(
            {"key": key, "skeleton": skel, "meta": meta, "raw": raw},
            protocol=pickle.HIGHEST_PROTOCOL,
        )
        blob = ckpt_io.dumps_framed(host, level=self.cold_level, meta=frame_meta)
        if self.faults is not None:
            blob = self.faults.on_cold_write(key, blob)  # torn-write injection
        os.makedirs(self.cold_dir, exist_ok=True)
        path = self._cold_path(key)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
        self._cold[key] = ColdEntry(path, skel, len(blob), raw, meta)
        del self._warm[key]
        del self._warm_bytes[key]
        self._warm_meta.pop(key, None)
        self.stats["demotions"] += 1
        self._write_manifest_locked()
        return True

    # -- manifest + recovery (ISSUE 8) ------------------------------------
    def _manifest_path(self) -> str:
        return os.path.join(self.cold_dir, MANIFEST_NAME)

    def _write_manifest_locked(self) -> None:
        """Atomically mirror the cold index to disk. The manifest is the
        authoritative key->file map (collision-proof vs filename mangling)
        and the fast path for :meth:`recover`; blobs stay self-describing
        as the fallback."""
        if self.cold_dir is None:
            return
        os.makedirs(self.cold_dir, exist_ok=True)
        entries = {
            key: {
                "file": os.path.basename(e.path),
                "comp": e.comp_bytes,
                "raw": e.raw_bytes,
            }
            for key, e in self._cold.items()
        }
        payload = pickle.dumps(
            {"version": MANIFEST_VERSION, "entries": entries},
            protocol=pickle.HIGHEST_PROTOCOL,
        )
        tmp = self._manifest_path() + ".tmp"
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, self._manifest_path())

    def _load_manifest(self) -> dict:
        try:
            with open(self._manifest_path(), "rb") as f:
                data = pickle.loads(f.read())
            if data.get("version") != MANIFEST_VERSION:
                return {"entries": {}}
            return data
        except FileNotFoundError:
            return {"entries": {}}
        except Exception:
            # a torn manifest write never happened (atomic replace), but a
            # corrupted file must not block recovery: blobs self-describe
            return {"entries": {}, "corrupt": True}

    def recover(self, cold_dir: Optional[str] = None, *,
                verify_payloads: bool = False) -> dict:
        """Rebuild the cold index (skeletons included) from disk after a
        process restart. Manifest entries are adopted first; blob files the
        manifest does not know about (a crash between the blob write and
        the manifest write) are adopted from their embedded frame metadata.
        Unreadable/corrupt blobs are quarantined, manifest entries whose
        file vanished are counted lost — recovery itself never raises on
        bad data. ``verify_payloads=True`` additionally checks every
        payload digest up front (reads every blob fully)."""
        if cold_dir is not None:
            self.cold_dir = cold_dir
        report = {
            "recovered": [], "quarantined": [], "lost": [],
            "orphans_adopted": [], "manifest_corrupt": False,
        }
        if self.cold_dir is None or not os.path.isdir(self.cold_dir):
            return report
        manifest = self._load_manifest()
        report["manifest_corrupt"] = bool(manifest.get("corrupt"))
        seen_files = set()
        for key, ent in manifest.get("entries", {}).items():
            fname = ent.get("file", "")
            seen_files.add(fname)
            path = os.path.join(self.cold_dir, fname)
            if not os.path.exists(path):
                report["lost"].append(key)
                with self._lock:
                    self.stats["lost"] += 1
                continue
            self._adopt_blob(path, report, verify=verify_payloads)
        # orphan blobs: written, crashed before their manifest update
        try:
            listing = sorted(os.listdir(self.cold_dir))
        except OSError:
            listing = []
        for fname in listing:
            if not fname.endswith(BLOB_SUFFIX) or fname in seen_files:
                continue
            adopted = self._adopt_blob(
                os.path.join(self.cold_dir, fname), report, verify=verify_payloads
            )
            if adopted is not None:
                report["orphans_adopted"].append(adopted)
        with self._lock:
            self.stats["recovered"] += len(report["recovered"])
            self._write_manifest_locked()
        return report

    def _adopt_blob(self, path: str, report: dict, *, verify: bool) -> Optional[str]:
        """Validate one blob file and (re)index it; quarantine on any
        integrity failure. Returns the adopted key, or None."""
        try:
            meta_bytes = ckpt_io.read_frame_meta(path)
            info = pickle.loads(meta_bytes)
            key = info["key"]
            skel, meta, raw = info["skeleton"], info.get("meta"), info["raw"]
            if verify:
                with open(path, "rb") as f:
                    ckpt_io.unframe(f.read(), verify=True)
        except FileNotFoundError:
            report["lost"].append(os.path.basename(path))
            with self._lock:
                self.stats["lost"] += 1
            return None
        except Exception as e:  # CorruptBlobError, bad pickle, short file...
            q = self._quarantine_file(path)
            report["quarantined"].append(
                {"file": os.path.basename(path), "reason": repr(e),
                 "quarantined_to": q}
            )
            return None
        with self._lock:
            if key in self._warm or key in self._cold:
                return None  # live state wins over a stale on-disk copy
            self._cold[key] = ColdEntry(
                path, skel, os.path.getsize(path), raw, meta
            )
        report["recovered"].append(key)
        return key

    def _quarantine_file(self, path: str) -> Optional[str]:
        """Move a bad blob into ``cold_dir/quarantine/`` (never delete —
        the bytes may matter for forensics). Returns the new path."""
        qdir = self.quarantine_dir()
        if qdir is None:
            try:
                os.remove(path)
            except OSError:
                pass
            with self._lock:
                self.stats["quarantined"] += 1
            return None
        try:
            os.makedirs(qdir, exist_ok=True)
            dest = os.path.join(qdir, os.path.basename(path))
            os.replace(path, dest)
        except OSError:
            dest = None
        with self._lock:
            self.stats["quarantined"] += 1
        return dest

    # -- promotion (cold/warm -> host pytree -> device) -------------------
    def _read_cold_blob(self, key: str, path: str) -> bytes:
        with open(path, "rb") as f:
            data = f.read()
        if self.faults is not None:
            data = self.faults.on_cold_read(key, data)  # may raise / mutate
        return data

    def get_host(self, key: str, *, verify: bool = True):
        """Synchronously read a snapshot back as a numpy pytree (no tier
        mutation — the entry stays parked until `drop()`).

        Every cold read verifies the blob's frame checksum (``verify=False``
        is the bench's overhead-measurement arm only). A corrupt or
        truncated blob is quarantined and surfaces as
        :class:`SnapshotLostError`; a concurrent ``drop()``/re-``put()``
        that unlinks the file mid-read resolves to the CURRENT state of the
        key (warm copy, or a clean ``KeyError``) instead of leaking
        ``FileNotFoundError``."""
        with self._lock:
            if key in self._warm:
                return self._warm[key]
            if key in self._cold:
                entry = self._cold[key]
            else:
                raise KeyError(f"no hibernated snapshot for {key!r}")
        try:
            blob = self._read_cold_blob(key, entry.path)
        except FileNotFoundError:
            return self._resolve_vanished(key, entry)
        with self._lock:
            cur = self._cold.get(key)
            if cur is not entry:
                # raced a re-put/drop while reading: the bytes we hold are
                # stale — defer to whatever the key is NOW
                if key in self._warm:
                    return self._warm[key]
                if cur is None:
                    raise KeyError(f"no hibernated snapshot for {key!r}")
                entry = cur  # re-demoted: fall through and decode fresh index
        try:
            tree = ckpt_io.loads_framed(blob, entry.skeleton, numpy=True, verify=verify)
        except ckpt_io.CorruptBlobError as e:
            with self._lock:
                if self._cold.get(key) is entry:
                    del self._cold[key]
                    self.stats["lost"] += 1
                    self._write_manifest_locked()
            self._quarantine_file(entry.path)
            raise SnapshotLostError(
                key, f"cold blob failed integrity check ({e}); quarantined"
            ) from e
        with self._lock:
            self.stats["cold_reads"] += 1
        return tree

    def _resolve_vanished(self, key: str, entry: ColdEntry):
        """The blob file disappeared mid-read. A concurrent drop/re-put is
        benign (the key's CURRENT state answers); a file missing while the
        index still points at it is permanent loss."""
        with self._lock:
            if key in self._warm:
                return self._warm[key]
            cur = self._cold.get(key)
            if cur is None:
                raise KeyError(f"no hibernated snapshot for {key!r}")
            if cur is entry:
                del self._cold[key]
                self.stats["lost"] += 1
                self._write_manifest_locked()
                raise SnapshotLostError(key, "cold blob file missing")
        # the entry was replaced (re-demoted) while we read: try the new one
        return self.get_host(key)

    def prefetch(
        self,
        key: str,
        put_fn: Optional[Callable[[Any], Any]] = None,
        *,
        retries: Optional[int] = None,
        backoff_s: Optional[float] = None,
        deadline_s: Optional[float] = None,
    ) -> WakeTicket:
        """Kick off an async promotion; `put_fn` (if given) runs on the
        worker thread — pass `jax.device_put` with the target sharding so
        the host->device copy overlaps the in-flight window.

        Transient I/O failures (``OSError``) retry up to ``retries`` times
        with exponential backoff (``backoff_s * 2**attempt``, capped);
        permanent failures — missing key, quarantined blob, exhausted
        retries, a raising ``put_fn`` — land the ticket in the terminal
        *failed* state, surfaced at ``result()`` / ``failed()``.
        ``deadline_s`` bounds the whole promotion: an overdue ticket fails
        with ``TimeoutError`` even if the worker is stuck."""
        if key not in self:
            raise KeyError(f"no hibernated snapshot for {key!r}")
        deadline = None if deadline_s is None else time.monotonic() + deadline_s
        ticket = WakeTicket(key, deadline=deadline)
        with self._lock:
            self.stats["prefetches"] += 1
        self._ensure_worker()
        self._work.put((
            ticket,
            put_fn,
            self.wake_retries if retries is None else retries,
            self.wake_backoff_s if backoff_s is None else backoff_s,
        ))
        return ticket

    def _ensure_worker(self) -> None:
        with self._lock:
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(
                    target=self._worker_loop, name="synapse-prefetch", daemon=True
                )
                self._worker.start()

    def heal_worker(self) -> int:
        """Supervision: if the prefetch worker thread died (an injected
        ``BaseException``, a segfaulting extension, ...), fail the ticket it
        was holding — its waiter must see a terminal state, not hang — and
        respawn the thread so queued tickets keep draining. Returns the
        number of tickets failed. Safe to call any time; a healthy worker
        makes this a no-op."""
        with self._lock:
            worker, inflight = self._worker, self._inflight
            if worker is None or worker.is_alive():
                return 0
            self._inflight = None
            self.stats["worker_respawns"] += 1
        failed = 0
        if inflight is not None and not inflight.ready():
            if inflight._fail(WorkerDiedError(
                f"prefetch worker died while promoting {inflight.key!r}"
            )):
                failed += 1
                with self._lock:
                    self.stats["prefetch_errors"] += 1
        self._ensure_worker()
        return failed

    def _worker_loop(self) -> None:
        while True:
            ticket, put_fn, retries, backoff = self._work.get()
            with self._lock:
                self._inflight = ticket
            try:
                self._run_prefetch(ticket, put_fn, retries, backoff)
            except Exception as e:  # surfaced at ticket.result()/failed()
                # NOT BaseException: KeyboardInterrupt/SystemExit must kill
                # the thread (heal_worker resurrects it and fails the
                # ticket) instead of being swallowed into a ticket error
                if ticket._fail(e):
                    with self._lock:
                        self.stats["prefetch_errors"] += 1
            with self._lock:
                self._inflight = None

    def _run_prefetch(self, ticket: WakeTicket, put_fn, retries: int,
                      backoff: float) -> None:
        attempt = 0
        while True:
            if ticket.ready():
                return  # expired host-side while queued/retrying
            if ticket.expire():
                with self._lock:
                    self.stats["prefetch_errors"] += 1
                return
            try:
                host = self.get_host(ticket.key)
                if self.faults is not None and put_fn is not None:
                    self.faults.on_put_fn(ticket.key)  # slow/blocked put
                value = put_fn(host) if put_fn is not None else host
                if put_fn is not None:
                    # force the copies to be enqueued/realized off-thread
                    jax.block_until_ready(value)
                if ticket._resolve(value):
                    return
                return  # lost the race to a host-side expiry
            except KeyError:
                raise  # SnapshotLostError / plain miss: permanent, no retry
            except OSError:
                attempt += 1
                if attempt > retries:
                    raise
                with self._lock:
                    self.stats["wake_retries"] += 1
                delay = min(backoff * (2 ** (attempt - 1)), self.wake_backoff_cap_s)
                if ticket.deadline is not None:
                    rem = ticket.remaining()
                    if rem is not None:
                        delay = min(delay, rem)
                time.sleep(delay)

    def drop(self, key: str) -> None:
        """Forget a snapshot (agent is hot again, or discarded)."""
        with self._lock:
            self._warm.pop(key, None)
            self._warm_bytes.pop(key, None)
            self._warm_meta.pop(key, None)
            entry = self._cold.pop(key, None)
            if entry is not None:
                # under the lock for the same reason as put(): a concurrent
                # re-put could re-demote to the same path between our pop and
                # an unlocked unlink, losing the new blob
                try:
                    os.remove(entry.path)
                except OSError:
                    pass
                self._write_manifest_locked()

    # -- accounting -------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        with self._lock:
            cold_disk = sum(e.comp_bytes for e in self._cold.values())
            cold_raw = sum(e.raw_bytes for e in self._cold.values())
            return {
                "n_warm": len(self._warm),
                "n_cold": len(self._cold),
                "warm_bytes": sum(self._warm_bytes.values()),
                "cold_bytes": cold_disk,
                "cold_raw_bytes": cold_raw,
                "cold_enabled": self.cold_enabled,
                **{f"stat_{k}": v for k, v in self.stats.items()},
            }
