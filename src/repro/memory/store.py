"""SynapseStore: the warm/cold tiers of the agent-memory hierarchy.

Tiers (paper §"million-agent capacity"; cache-hierarchy treatment per
"Multi-Agent Memory from a Computer Architecture Perspective"):

* **hot**  — a live lane inside the engine's `TickState` on device. Not
  stored here; the store only sees agents once they leave the device.
* **warm** — host RAM: the agent's landmark-compressed cache slice plus
  per-lane scalars, as a numpy pytree (exact device bytes, no re-encode).
* **cold** — disk: the same pytree through the `checkpoint/io` codec
  (msgpack + zstd), one blob per agent; only a ShapeDtypeStruct skeleton
  stays in RAM so a million cold agents cost ~nothing on the host.

Demotion warm→cold is LRU, triggered when `warm_capacity_bytes` is
exceeded (and on explicit `demote()`); it needs the optional `zstandard`
dep — without it (or without a `cold_dir`) entries simply stay warm and
the skip is counted in the report rather than raised mid-run.

Promotion is asynchronous: `prefetch()` hands back a `WakeTicket` and a
daemon worker thread reads the blob / host pytree and (optionally) lands
it on device via the caller's `put_fn` (e.g. `jax.device_put` with the
replicated sharding). `transfer_guard` contexts are thread-local in JAX,
so the worker's explicit transfers never trip the engine's "no transfers
in the overlap region" invariant — the engine only *commits* the already
device-resident buffers at a window boundary.

Snapshots are stored bitwise: a wake must reproduce the exact greedy
stream of a lane that never hibernated, so nothing here may re-quantize.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import io as ckpt_io
from ..core.prism import tree_bytes

WARM = "warm"
COLD = "cold"


def _host_tree(tree):
    """Materialize any (device or host) pytree as numpy leaves."""
    return jax.tree_util.tree_map(np.asarray, jax.device_get(tree))


def _skeleton(tree):
    """Shape/dtype-only skeleton — what stays in RAM for a cold agent."""
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), tree
    )


class WakeTicket:
    """Handle for an in-flight asynchronous promotion (wake prefetch)."""

    def __init__(self, key: str):
        self.key = key
        self._done = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    def _resolve(self, value: Any) -> None:
        self._value = value
        self._done.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._done.set()

    def ready(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._done.wait(timeout):
            raise TimeoutError(f"wake prefetch for {self.key!r} still in flight")
        if self._error is not None:
            raise self._error
        return self._value


class SynapseStore:
    """Warm (host RAM) + cold (zstd disk) storage for hibernated agents."""

    def __init__(
        self,
        *,
        warm_capacity_bytes: Optional[int] = None,
        cold_dir: Optional[str] = None,
        cold_level: int = 3,
    ):
        self.warm_capacity_bytes = warm_capacity_bytes
        self.cold_dir = cold_dir
        self.cold_level = cold_level
        self._lock = threading.RLock()
        # key -> numpy pytree; insertion order doubles as LRU order
        self._warm: Dict[str, Any] = {}
        self._warm_bytes: Dict[str, int] = {}
        # key -> (path, skeleton, compressed_bytes, raw_bytes)
        self._cold: Dict[str, tuple] = {}
        self.stats = {
            "puts": 0,
            "demotions": 0,
            "demotions_skipped": 0,
            "prefetches": 0,
            "cold_reads": 0,
        }
        self._work: "queue.SimpleQueue" = queue.SimpleQueue()
        self._worker: Optional[threading.Thread] = None

    # -- tier plumbing ----------------------------------------------------
    @property
    def cold_enabled(self) -> bool:
        return self.cold_dir is not None and ckpt_io.zstandard is not None

    def _cold_path(self, key: str) -> str:
        safe = "".join(c if (c.isalnum() or c in "._-") else "_" for c in key)
        return os.path.join(self.cold_dir, f"{safe}.synapse.zst")

    def warm_bytes(self) -> int:
        with self._lock:
            return sum(self._warm_bytes.values())

    def keys(self):
        with self._lock:
            return list(self._warm) + list(self._cold)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._warm or key in self._cold

    def tier_of(self, key: str) -> Optional[str]:
        with self._lock:
            if key in self._warm:
                return WARM
            if key in self._cold:
                return COLD
            return None

    # -- demotion (device -> warm -> cold) --------------------------------
    def put(self, key: str, tree) -> None:
        """Park a snapshot in the warm tier (demoting LRU entries to cold
        if over capacity). `tree` may hold device or numpy leaves."""
        host = _host_tree(tree)
        with self._lock:
            stale = self._cold.pop(key, None)
            self._warm.pop(key, None)  # re-put refreshes LRU position
            self._warm[key] = host
            self._warm_bytes[key] = tree_bytes(host)
            self.stats["puts"] += 1
            self._enforce_capacity_locked()
        if stale is not None:  # superseded cold blob must not leak on disk
            try:
                os.remove(stale[0])
            except OSError:
                pass

    def _enforce_capacity_locked(self) -> None:
        if self.warm_capacity_bytes is None:
            return
        while sum(self._warm_bytes.values()) > self.warm_capacity_bytes and self._warm:
            oldest = next(iter(self._warm))
            if not self._demote_locked(oldest):
                self.stats["demotions_skipped"] += 1
                break  # no cold backing: stay warm rather than drop state

    def demote(self, key: str) -> bool:
        """Explicitly push one warm entry to the cold tier."""
        with self._lock:
            return self._demote_locked(key)

    def demote_lru(self) -> Optional[str]:
        with self._lock:
            if not self._warm:
                return None
            oldest = next(iter(self._warm))
            return oldest if self._demote_locked(oldest) else None

    def _demote_locked(self, key: str) -> bool:
        if key not in self._warm or not self.cold_enabled:
            return False
        host = self._warm[key]
        blob = ckpt_io.dumps(host, level=self.cold_level)
        os.makedirs(self.cold_dir, exist_ok=True)
        path = self._cold_path(key)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
        raw = self._warm_bytes[key]
        self._cold[key] = (path, _skeleton(host), len(blob), raw)
        del self._warm[key]
        del self._warm_bytes[key]
        self.stats["demotions"] += 1
        return True

    # -- promotion (cold/warm -> host pytree -> device) -------------------
    def get_host(self, key: str):
        """Synchronously read a snapshot back as a numpy pytree (no tier
        mutation — the entry stays parked until `drop()`)."""
        with self._lock:
            if key in self._warm:
                return self._warm[key]
            if key in self._cold:
                path, skel, _, _ = self._cold[key]
            else:
                raise KeyError(f"no hibernated snapshot for {key!r}")
        with open(path, "rb") as f:
            blob = f.read()
        with self._lock:
            self.stats["cold_reads"] += 1
        return ckpt_io.loads(blob, skel, numpy=True)

    def prefetch(
        self, key: str, put_fn: Optional[Callable[[Any], Any]] = None
    ) -> WakeTicket:
        """Kick off an async promotion; `put_fn` (if given) runs on the
        worker thread — pass `jax.device_put` with the target sharding so
        the host->device copy overlaps the in-flight window."""
        if key not in self:
            raise KeyError(f"no hibernated snapshot for {key!r}")
        ticket = WakeTicket(key)
        with self._lock:
            self.stats["prefetches"] += 1
        self._ensure_worker()
        self._work.put((ticket, put_fn))
        return ticket

    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._worker_loop, name="synapse-prefetch", daemon=True
            )
            self._worker.start()

    def _worker_loop(self) -> None:
        while True:
            ticket, put_fn = self._work.get()
            try:
                host = self.get_host(ticket.key)
                value = put_fn(host) if put_fn is not None else host
                if put_fn is not None:
                    # force the copies to be enqueued/realized off-thread
                    jax.block_until_ready(value)
                ticket._resolve(value)
            except BaseException as e:  # surfaced at ticket.result()
                ticket._fail(e)

    def drop(self, key: str) -> None:
        """Forget a snapshot (agent is hot again, or discarded)."""
        with self._lock:
            self._warm.pop(key, None)
            self._warm_bytes.pop(key, None)
            entry = self._cold.pop(key, None)
        if entry is not None:
            try:
                os.remove(entry[0])
            except OSError:
                pass

    # -- accounting -------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        with self._lock:
            cold_disk = sum(e[2] for e in self._cold.values())
            cold_raw = sum(e[3] for e in self._cold.values())
            return {
                "n_warm": len(self._warm),
                "n_cold": len(self._cold),
                "warm_bytes": sum(self._warm_bytes.values()),
                "cold_bytes": cold_disk,
                "cold_raw_bytes": cold_raw,
                "cold_enabled": self.cold_enabled,
                **{f"stat_{k}": v for k, v in self.stats.items()},
            }
