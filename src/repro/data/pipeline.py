"""Deterministic synthetic data pipeline.

Three sources, mixed per document:
  * "copy":   A<sep>A — forces content-addressable attention (the synapse
              quality benchmark uses this: landmark selection must keep the
              payload tokens).
  * "arith":  byte-rendered modular additions "12+34=46;" — learnable
              structure for the ~100M end-to-end training example.
  * "lm":     Zipf-distributed byte n-gram soup — generic LM load.

Also provides embedding batches for the stubbed-frontend archs (audio/vlm)
and ``input_specs`` ShapeDtypeStructs for the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 256
    batch_size: int = 8
    vocab_size: int = 512
    mix: tuple[float, float, float] = (0.3, 0.4, 0.3)  # copy, arith, lm
    seed: int = 0


class SyntheticCorpus:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab_size, 256)
        ranks = np.arange(1, v + 1)
        self.zipf = (1.0 / ranks) / np.sum(1.0 / ranks)

    def _doc_copy(self, n: int) -> np.ndarray:
        half = max(2, n // 2 - 1)
        payload = self.rng.integers(ord("a"), ord("z") + 1, size=half)
        sep = np.asarray([ord("|")])
        doc = np.concatenate([payload, sep, payload])
        return doc[:n]

    def _doc_arith(self, n: int) -> np.ndarray:
        out = []
        while sum(len(o) for o in out) < n:
            a, b = self.rng.integers(0, 100, size=2)
            out.append(np.frombuffer(f"{a}+{b}={(a + b) % 100};".encode(), dtype=np.uint8).astype(np.int64))
        return np.concatenate(out)[:n]

    def _doc_lm(self, n: int) -> np.ndarray:
        v = len(self.zipf)
        return self.rng.choice(v, size=n, p=self.zipf)

    def batch(self) -> dict:
        """-> {"tokens": [B,S] int32, "labels": [B,S] int32}."""
        B, S = self.cfg.batch_size, self.cfg.seq_len
        toks = np.zeros((B, S + 1), np.int32)
        kinds = self.rng.choice(3, size=B, p=np.asarray(self.cfg.mix))
        for i, kind in enumerate(kinds):
            doc = (self._doc_copy, self._doc_arith, self._doc_lm)[kind](S + 1)
            toks[i, : len(doc)] = doc
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def embed_batch(self, d_model: int, with_positions_3d: bool = False) -> dict:
        """Stub-frontend batch: frame/patch embeddings + byte-bucket labels."""
        B, S = self.cfg.batch_size, self.cfg.seq_len
        emb = self.rng.standard_normal((B, S, d_model), dtype=np.float32)
        labels = self.rng.integers(0, self.cfg.vocab_size, size=(B, S)).astype(np.int32)
        out = {"embeds": emb, "labels": labels}
        if with_positions_3d:
            pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, None, :], (B, 3, S)).copy()
            out["positions"] = pos
        return out


def make_batch(cfg: ModelConfig, data_cfg: DataConfig) -> dict:
    corpus = SyntheticCorpus(
        DataConfig(
            seq_len=data_cfg.seq_len,
            batch_size=data_cfg.batch_size,
            vocab_size=cfg.vocab_size,
            mix=data_cfg.mix,
            seed=data_cfg.seed,
        )
    )
    if cfg.embed_inputs:
        return corpus.batch()
    return corpus.embed_batch(cfg.d_model, with_positions_3d=cfg.rope_kind == "mrope")
