"""Byte-level tokenizer with a few control specials.

Deterministic, dependency-free: token ids 0..255 are raw bytes; specials
follow. Enough for the engine demos, router-trigger round-trips, and the
synthetic training pipeline. Configs with larger vocabs simply leave the
tail unused (ids < vocab_size always holds for vocab >= 272).

:class:`Utf8StreamDecoder` is the streaming counterpart of
:meth:`ByteTokenizer.decode` (ISSUE 9): token ids arrive in arbitrary
chunks — one per step on the serving path, one window per drain on the
engine path — and a multi-byte UTF-8 codepoint may split across any chunk
boundary. Decoding each chunk independently with ``errors="replace"``
turns every split codepoint into U+FFFD garbage; the stream decoder
buffers the incomplete trailing sequence instead, so the concatenation of
its outputs (plus a final :meth:`~Utf8StreamDecoder.flush`) is bitwise
identical to ``decode(all_ids)`` no matter where the chunks were cut.
"""
from __future__ import annotations

import codecs

import numpy as np

SPECIALS = ["<pad>", "<bos>", "<eos>", "<task>", "<answer>"]


class ByteTokenizer:
    def __init__(self, vocab_size: int = 512):
        assert vocab_size >= 256 + len(SPECIALS), vocab_size
        self.vocab_size = vocab_size
        self.pad_id = 256
        self.bos_id = 257
        self.eos_id = 258

    def encode(self, text: str, *, bos: bool = False, eos: bool = False) -> list[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.bos_id] + ids
        if eos:
            ids = ids + [self.eos_id]
        return ids

    def decode(self, ids) -> str:
        out = bytearray()
        for i in np.asarray(ids).tolist():
            if 0 <= i < 256:
                out.append(i)
        return out.decode("utf-8", errors="replace")

    def stream_decoder(self) -> "Utf8StreamDecoder":
        return Utf8StreamDecoder(self)


class Utf8StreamDecoder:
    """Stateful incremental decoder over byte-token ids.

    Invariant (asserted by tests/test_utf8_stream.py over every split
    point): for ANY partition of ``ids`` into chunks,

        "".join(dec.feed(c) for c in chunks) + dec.flush()
            == tokenizer.decode(ids)

    bitwise — including invalid byte sequences, which replace with U+FFFD
    under the exact same maximal-subpart rules as the one-shot decode.
    Backed by CPython's incremental UTF-8 codec (the machinery under
    TextIOWrapper), whose only state is the buffered incomplete trailing
    sequence (<= 3 bytes): :attr:`pending` exports it so a hibernated
    agent's half-received codepoint survives a park/wake or a process
    crash and the stream resumes bitwise.
    """

    def __init__(self, tokenizer: ByteTokenizer):
        self.tok = tokenizer
        self._dec = codecs.getincrementaldecoder("utf-8")("replace")

    def feed(self, ids) -> str:
        """Decode a chunk of token ids; returns only the complete text
        (an incomplete trailing codepoint stays buffered for the next
        chunk). Non-byte ids (specials, ring padding) are skipped exactly
        as :meth:`ByteTokenizer.decode` skips them."""
        raw = bytes(i for i in np.asarray(ids, dtype=np.int64).tolist() if 0 <= i < 256)
        return self._dec.decode(raw, False)

    def flush(self) -> str:
        """End of stream: replace any buffered incomplete sequence (this is
        what makes the final text equal the one-shot decode bitwise)."""
        return self._dec.decode(b"", True)

    @property
    def pending(self) -> bytes:
        """The buffered incomplete trailing sequence (b"" when aligned)."""
        return self._dec.getstate()[0]

    def tail(self) -> str:
        """What :meth:`flush` WOULD emit, without consuming the state —
        lets callers peek at the end-of-stream text mid-flight."""
        return self.pending.decode("utf-8", errors="replace")

    def restore(self, pending: bytes) -> None:
        """Rehydrate after hibernate/crash-recovery: resume mid-codepoint."""
        self._dec.reset()
        self._dec.setstate((bytes(pending), 0))
