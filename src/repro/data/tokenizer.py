"""Byte-level tokenizer with a few control specials.

Deterministic, dependency-free: token ids 0..255 are raw bytes; specials
follow. Enough for the engine demos, router-trigger round-trips, and the
synthetic training pipeline. Configs with larger vocabs simply leave the
tail unused (ids < vocab_size always holds for vocab >= 272).
"""
from __future__ import annotations

import numpy as np

SPECIALS = ["<pad>", "<bos>", "<eos>", "<task>", "<answer>"]


class ByteTokenizer:
    def __init__(self, vocab_size: int = 512):
        assert vocab_size >= 256 + len(SPECIALS), vocab_size
        self.vocab_size = vocab_size
        self.pad_id = 256
        self.bos_id = 257
        self.eos_id = 258

    def encode(self, text: str, *, bos: bool = False, eos: bool = False) -> list[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.bos_id] + ids
        if eos:
            ids = ids + [self.eos_id]
        return ids

    def decode(self, ids) -> str:
        out = bytearray()
        for i in np.asarray(ids).tolist():
            if 0 <= i < 256:
                out.append(i)
        return out.decode("utf-8", errors="replace")
