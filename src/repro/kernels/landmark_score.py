"""Pallas TPU kernel: fused hybrid landmark scoring pass (paper §3.3).

One sweep over the KV cache computing BOTH selection terms per key:
  * raw attention logits per query head (density term, pre-softmax — the
    softmax normalizer is a cheap [B,H,T] reduction done by the wrapper), and
  * min distance to the current landmark set (coverage term),
so keys are read from HBM exactly once instead of twice. This is the
bandwidth-bound half of the Topological Synapse; the tiny top-k/argmax that
follows is XLA-native.

Tiling: grid (B, T/blkT). Per program: keys block [blkT, Hkv, D] in VMEM,
queries [H, D], landmark centroids [Kc, D]. blkT, D multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, lm_ref, logits_ref, *maybe_dist_ref, scale: float, hkv: int, true_d: int, with_dist: bool):
    # q_ref:  [H, D]; k_ref: [blkT, Hkv*D]; lm_ref: [Kc, D]
    # logits_ref: [H, blkT]; dist_ref: [blkT] (absent when not with_dist)
    q = q_ref[...].astype(jnp.float32)            # [H, D]
    kflat = k_ref[...].astype(jnp.float32)        # [blkT, Hkv*D]
    blk_t = kflat.shape[0]
    d = q.shape[1]
    h = q.shape[0]
    g = h // hkv
    k = kflat.reshape(blk_t, hkv, d)

    # density term: per-head q.k logits; head h uses kv head h // G
    # compute per kv head then broadcast to its group rows
    # s[kv, G, blkT]
    qg = q.reshape(hkv, g, d)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=jnp.float32
    )  # [Hkv, G, blkT]
    logits_ref[...] = (s.reshape(h, blk_t) * scale).astype(logits_ref.dtype)

    if not with_dist:
        return
    dist_ref = maybe_dist_ref[0]
    # coverage term: min_j || mean_kv(k_t) - lm_j || / sqrt(d)
    lm = lm_ref[...].astype(jnp.float32)          # [Kc, D]
    pooled = jnp.mean(k, axis=1)  # [blkT, D]
    k2 = jnp.sum(pooled * pooled, axis=-1, keepdims=True)        # [blkT, 1]
    l2 = jnp.sum(lm * lm, axis=-1)[None, :]                      # [1, Kc]
    cross = jax.lax.dot_general(
        pooled, lm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [blkT, Kc]
    d2 = jnp.maximum(k2 + l2 - 2.0 * cross, 0.0)
    dist_ref[...] = jnp.sqrt(jnp.min(d2, axis=-1) / true_d).astype(dist_ref.dtype)


def landmark_score(q, keys, landmarks=None, *, scale: float | None = None, true_d: int | None = None, block_t: int = 512, interpret: bool = False):
    """q: [B, H, D]; keys: [B, T, Hkv, D]; landmarks: [B, Kc, D] (pooled),
    or None for the density-only sweep (the coverage block is skipped).

    Returns (logits [B, H, T] f32 — pre-softmax density logits,
             min_dist [B, T] f32 — normalized distance to landmark set, or
             None when landmarks is None).
    T must be a multiple of block_t; D multiple of 128 (ops.py pads).
    """
    B, H, D = q.shape
    T, Hkv = keys.shape[1], keys.shape[2]
    with_dist = landmarks is not None
    if not with_dist:
        landmarks = jnp.zeros((B, 1, D), q.dtype)  # placeholder operand, unread
    Kc = landmarks.shape[1]
    scale = (1.0 / (D ** 0.5)) if scale is None else scale
    true_d = D if true_d is None else true_d
    kflat = keys.reshape(B, T, Hkv * D)
    grid = (B, T // block_t)
    out_specs = [pl.BlockSpec((None, H, block_t), lambda b, t: (b, 0, t))]
    out_shape = [jax.ShapeDtypeStruct((B, H, T), jnp.float32)]
    if with_dist:
        out_specs.append(pl.BlockSpec((None, block_t), lambda b, t: (b, t)))
        out_shape.append(jax.ShapeDtypeStruct((B, T), jnp.float32))
    res = pl.pallas_call(
        functools.partial(_kernel, scale=scale, hkv=Hkv, true_d=true_d, with_dist=with_dist),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, H, D), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((None, block_t, Hkv * D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((None, Kc, D), lambda b, t: (b, 0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(q, kflat, landmarks)
    return (res[0], res[1]) if with_dist else (res[0], None)
