"""Jit'd public wrappers around the Pallas kernels.

Handles padding to TPU tile alignment (T, D multiples of 128), dtype policy,
and the interpret-mode switch (CPU container: interpret=True executes the
kernel body in Python for correctness; on TPU the same code compiles to
Mosaic). ``INTERPRET`` auto-detects the backend.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import landmark_score as _ls
from repro.kernels import ref as _ref
from repro.kernels import synapse_attention as _sa

INTERPRET = jax.default_backend() != "tpu"
# finite mask shared with the kernels AND the per-lane sampler: keeps
# all-invalid rows NaN-free
NEG_INF = _sa.NEG_INF


def ring_append(ring, vals, cursor):
    """Append one column to the device token rings: ring [B, R] <- vals [B]
    at column ``cursor`` ([] int32, traced).

    The rings are the engine's zero-host-sync drain buffers; inside the
    macro-tick ``lax.scan`` the cursor is the scan carry, so the same
    program serves every virtual tick of a window.
    """
    return jax.lax.dynamic_update_slice(
        ring, vals.astype(ring.dtype)[:, None], (jnp.zeros_like(cursor), cursor)
    )


def _pad_to(x, axis: int, mult: int, value=0.0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.jit, static_argnames=("interpret", "scale"))
def synapse_attention(q, keys, values, valid, *, scale: float | None = None, interpret: bool | None = None):
    """Padded/aligned wrapper. q [B,H,D]; keys/values [B,T,Hkv,D]; valid [B,T].
    ``scale`` defaults to 1/sqrt(D of q).

    Tile alignment only matters for the compiled Mosaic path; under
    interpret mode padding just multiplies the emulated kernel's work (and
    materializes pad/slice ops), so the CPU path runs the true shapes.
    """
    interpret = INTERPRET if interpret is None else interpret
    B, H, D = q.shape
    T = keys.shape[1]
    scale = 1.0 / (D ** 0.5) if scale is None else scale
    if interpret:
        if T <= 512:
            # decode-sized problems: the Pallas interpreter's grid/blocking
            # machinery costs more than the math — the jnp oracle computes
            # the same masked softmax attend (same NEG_INF mask) faster on
            # CPU, and this is the engine's per-tick hot path
            return _ref.synapse_attention_ref(q, keys, values, valid, scale=scale)
        return _sa.synapse_attention(q, keys, values, valid, scale=scale, interpret=True)
    qp = _pad_to(q, 2, 128)
    kp = _pad_to(_pad_to(keys, 3, 128), 1, 128)
    vp = _pad_to(_pad_to(values, 3, 128), 1, 128)
    validp = _pad_to(valid, 1, 128, value=False)
    out, mass = _sa.synapse_attention(qp, kp, vp, validp, scale=scale, interpret=False)
    return out[:, :, :D], mass[:, :T]


def synapse_attend(q, pieces, valids, *, scale: float | None = None, policy=None):
    """Policy-routed attend over [landmarks; window; inject] k/v pieces —
    the single entry the synapse decode calls, threading the engine-owned
    ``SynapsePolicy`` (no module globals).

    Routing: a live token-shard axis — from ``policy.shard_axis`` or an
    enclosing :func:`repro.core.synapse_sharded.token_sharding` scope — or
    ``policy.attend_impl == "piece"`` selects the flash-decode
    ``piece_attend`` path; otherwise ONE fused :func:`synapse_attention`
    over the concatenated token set. Both paths reduce to the identical
    fused computation when no axis is live, so the choice never perturbs
    token streams (the lane-sharded engine's bitwise-parity contract).
    Returns (out [B,H,D], masses — one [B,T_i] per piece).
    """
    from repro.core import synapse_sharded as sharded  # deferred: no cycle

    ctx = sharded.current_context()
    p_axis = getattr(policy, "shard_axis", None)
    if p_axis is not None:
        ctx = sharded.ShardContext(p_axis, ctx.mesh)
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    if ctx.axis is not None or getattr(policy, "attend_impl", "pallas") == "piece":
        return sharded.piece_attend(q, pieces, valids, scale, ctx=ctx)
    sizes = [k.shape[1] for k, _ in pieces]
    k_all = jnp.concatenate([k for k, _ in pieces], axis=1)
    v_all = jnp.concatenate([v for _, v in pieces], axis=1)
    valid_all = jnp.concatenate(list(valids), axis=1)
    out, mass = synapse_attention(q, k_all, v_all, valid_all, scale=scale)
    splits = [sum(sizes[: i + 1]) for i in range(len(sizes) - 1)]
    return out, list(jnp.split(mass, splits, axis=1))


@partial(jax.jit, static_argnames=("interpret", "block_t"))
def landmark_score(q, keys, landmarks=None, valid=None, *, block_t: int = 512, interpret: bool | None = None):
    """Returns (density [B,T] — per-head softmax mass summed over heads,
    min_dist [B,T] — or None when ``landmarks`` is None: the coverage block
    of the kernel is skipped for density-only sweeps). Handles padding;
    softmax normalization over the true T. ``valid`` ([B,T] bool, optional)
    restricts the softmax to valid keys — the per-head normalizers only
    count the live prefix of the cache."""
    interpret = INTERPRET if interpret is None else interpret
    B, H, D = q.shape
    T = keys.shape[1]
    if interpret:
        # no tile alignment needed when emulating: one block over the true T
        logits, dist = _ls.landmark_score(
            q, keys, landmarks, scale=1.0 / (D ** 0.5), true_d=D, block_t=T, interpret=True
        )
    else:
        block_t = min(block_t, max(128, ((T + 127) // 128) * 128))
        qp = _pad_to(q, 2, 128)
        kp = _pad_to(_pad_to(keys, 3, 128), 1, block_t)
        lmp = None if landmarks is None else _pad_to(landmarks, 2, 128)
        logits, dist = _ls.landmark_score(
            qp, kp, lmp, scale=1.0 / (D ** 0.5), true_d=D, block_t=block_t, interpret=False
        )
        logits = logits[:, :, :T]
        dist = None if dist is None else dist[:, :T]
    if valid is not None:
        logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    density = jax.nn.softmax(logits, axis=-1).sum(axis=1)  # paper: sum_h softmax_h
    return density, dist
