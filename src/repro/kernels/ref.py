"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def synapse_attention_ref(q, keys, values, valid, scale: float | None = None):
    """q: [B,H,D]; keys/values: [B,T,Hkv,D]; valid: [B,T] bool."""
    B, H, D = q.shape
    Hkv = keys.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    k = keys.astype(jnp.float32)
    v = values.astype(jnp.float32)
    scale = 1.0 / np.sqrt(D) if scale is None else scale
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k) * scale
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v)
    mass = p.sum(axis=(1, 2))
    return out.reshape(B, H, D).astype(q.dtype), mass


def landmark_score_ref(q, keys, landmarks):
    """q: [B,H,D]; keys: [B,T,Hkv,D]; landmarks: [B,Kc,D] pooled centroids."""
    B, H, D = q.shape
    Hkv = keys.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    k = keys.astype(jnp.float32)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k) / np.sqrt(D)
    logits = logits.reshape(B, H, -1)
    pooled = k.mean(axis=2)  # [B,T,D]
    diff = pooled[:, :, None, :] - landmarks.astype(jnp.float32)[:, None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)  # [B,T,Kc]
    dist = jnp.sqrt(jnp.min(d2, axis=-1) / D)
    return logits, dist


def mamba2_chunk_ref(x, a_log_decay, b, c, *, chunk: int):
    """Reference chunked-SSD core (used by the mamba2_chunk kernel tests).

    x: [B,S,nh,dh] (dt-scaled inputs), a_log_decay: [B,S,nh] (log a_t, <=0),
    b, c: [B,S,ds]. Returns y [B,S,nh,dh] (no D-skip/gating — core only).
    """
    B, S, nh, dh = x.shape
    ds = b.shape[-1]
    y = jnp.zeros((B, S, nh, dh), jnp.float32)
    state = jnp.zeros((B, nh, dh, ds), jnp.float32)

    def step(state, inp):
        xt, la, bt, ct = inp
        a = jnp.exp(la)  # [B,nh]
        state = state * a[:, :, None, None] + jnp.einsum("bhd,bs->bhds", xt, bt)
        yt = jnp.einsum("bhds,bs->bhd", state, ct)
        return state, yt

    xs = (
        x.astype(jnp.float32).swapaxes(0, 1),
        a_log_decay.astype(jnp.float32).swapaxes(0, 1),
        b.astype(jnp.float32).swapaxes(0, 1),
        c.astype(jnp.float32).swapaxes(0, 1),
    )
    _, ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1)
