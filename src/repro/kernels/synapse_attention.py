"""Pallas TPU kernel: single-token decode attention over a synapse token set.

The per-tick hot loop of every Warp-Cortex agent: one query against the
concatenated [landmarks; window; inject] key set (T = K + W + J, a few
hundred to a few thousand — this is the whole point of the synapse). The
kernel fuses the masked attend AND the paper's density statistic (attention
mass per key, summed over heads) into one VMEM-resident pass, so the key set
is read from HBM exactly once per step.

Tiling: grid (B, Hkv); per program the full [T, D] K and V tiles for one kv
head live in VMEM (T<=8192, D<=256 -> <=8 MiB bf16), queries are the G = H/Hkv
group rows. Scores run in fp32 on the MXU; D and T should be multiples of
128 for lane alignment (callers pad — see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel_batched(q_ref, k_ref, v_ref, valid_ref, o_ref, mass_ref, *, scale: float):
    # Fat-block variant: the whole (B*Hkv) batch lives in ONE program.
    # q_ref:    [BB, G, D]; k_ref/v_ref: [BB, T, D]; valid_ref: [BB, T] int8
    # o_ref:    [BB, G, D]; mass_ref: [BB, T]
    # Used in interpret mode (CPU), where per-program interpreter overhead
    # dominates: grid (B, Hkv) costs ~B*Hkv program invocations, grid (1,)
    # costs one. On TPU the per-(b,h) grid below keeps [T, D] tiles aligned.
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    valid = valid_ref[...] != 0

    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    ) * scale  # [BB, G, T]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / denom
    o = jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # [BB, G, D]
    o_ref[...] = o.astype(o_ref.dtype)
    mass_ref[...] = jnp.sum(p, axis=1).astype(mass_ref.dtype)


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, mass_ref, *, scale: float):
    # q_ref:    [G, D]      queries of this kv head's group
    # k_ref:    [T, D]      keys (one kv head)
    # v_ref:    [T, D]      values
    # valid_ref:[T]         int8 mask
    # o_ref:    [G, D]      attention output
    # mass_ref: [T]         per-key probability mass summed over the G heads
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    valid = valid_ref[...] != 0

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [G, T]
    s = jnp.where(valid[None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / denom  # [G, T]
    o = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [G, D]
    o_ref[...] = o.astype(o_ref.dtype)
    mass_ref[...] = jnp.sum(p, axis=0).astype(mass_ref.dtype)


def synapse_attention(
    q, keys, values, valid, *, scale: float | None = None, interpret: bool = False,
    batched: bool | None = None,
):
    """q: [B, H, D]; keys/values: [B, T, Hkv, D]; valid: [B, T] bool.

    Returns (out [B, H, D], mass [B, T] f32). T and D must be multiples of
    128 (pad via ops.py wrapper). ``batched`` collapses the (B, Hkv) grid
    into one program — the default under interpret mode, where per-program
    overhead dominates the tiny decode shapes.
    """
    B, H, D = q.shape
    T, Hkv = keys.shape[1], keys.shape[2]
    G = H // Hkv
    scale = (1.0 / (D ** 0.5)) if scale is None else scale
    batched = interpret if batched is None else batched
    qg = q.reshape(B, Hkv, G, D)
    kt = keys.swapaxes(1, 2)  # [B, Hkv, T, D]
    vt = values.swapaxes(1, 2)
    valid8 = valid.astype(jnp.int8)

    if batched:
        BB = B * Hkv
        qb = qg.swapaxes(1, 0).reshape(BB, G, D)      # [Hkv*B, G, D]
        kb = kt.swapaxes(1, 0).reshape(BB, T, D)
        vb = vt.swapaxes(1, 0).reshape(BB, T, D)
        validb = jnp.tile(valid8, (Hkv, 1))           # [Hkv*B, T]
        out, mass = pl.pallas_call(
            functools.partial(_kernel_batched, scale=scale),
            grid=(1,),
            in_specs=[
                pl.BlockSpec((BB, G, D), lambda i: (0, 0, 0)),
                pl.BlockSpec((BB, T, D), lambda i: (0, 0, 0)),
                pl.BlockSpec((BB, T, D), lambda i: (0, 0, 0)),
                pl.BlockSpec((BB, T), lambda i: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((BB, G, D), lambda i: (0, 0, 0)),
                pl.BlockSpec((BB, T), lambda i: (0, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((BB, G, D), q.dtype),
                jax.ShapeDtypeStruct((BB, T), jnp.float32),
            ],
            interpret=interpret,
        )(qb, kb, vb, validb)
        out = out.reshape(Hkv, B, G, D).swapaxes(1, 0).reshape(B, H, D)
        mass = mass.reshape(Hkv, B, T).sum(axis=0)
        return out, mass

    grid = (B, Hkv)
    out, mass = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, G, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, T, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, T, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, T), lambda b, h: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, G, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, T), lambda b, h: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, T), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, valid8)
    return out.reshape(B, H, D), mass.sum(axis=1)
