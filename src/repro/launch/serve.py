"""Serving launcher: plain continuous-batching server or the Warp-Cortex
multi-agent engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-0.5b --mode cortex
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --mode batch

Crash recovery (ISSUE 8): point ``--cold-dir`` at a persistent directory
and a later run with ``--recover`` rebuilds the cold tier from disk
(integrity-checked; corrupt blobs quarantined) and re-adopts the agents it
finds — their streams continue bitwise where the dead process stopped.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, list_archs
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.memory import SynapseStore
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-0.5b", choices=list_archs())
    ap.add_argument("--mode", default="cortex", choices=["cortex", "batch"])
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--prompt", default="Question: what makes this system scale? [TASK: verify memory math] Answer:")
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--cold-dir", default=None,
                    help="directory for the cold (disk) tier; enables --recover")
    ap.add_argument("--recover", action="store_true",
                    help="rebuild the cold tier from --cold-dir and re-adopt "
                         "the hibernated agents found there before serving")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    store = SynapseStore(cold_dir=args.cold_dir) if args.cold_dir else None

    if args.mode == "batch":
        server = BatchServer(params, cfg, tok, n_lanes=4, capacity=512,
                             sampling=SamplingParams(temperature=0.9),
                             **({"store": store} if store else {}))
        server.submit(args.prompt, max_new_tokens=32)
        for r in server.run_until_done():
            print(f"[{r.rid}] {r.text!r}" + (f"  ERROR: {r.error}" if r.error else ""))
        return

    prism = Prism(params, cfg)
    engine = CortexEngine(prism, tok, n_main=1, max_side=4, main_capacity=512,
                          side_max_steps=12, theta=-1.0,
                          sampling=SamplingParams(temperature=1.0),
                          **({"store": store} if store else {}))
    if args.recover:
        if not args.cold_dir:
            ap.error("--recover requires --cold-dir")
        rec_report = engine.store.recover(args.cold_dir)
        adopted = engine.adopt_hibernated()
        print(f"recover: {len(rec_report['recovered'])} cold entries rebuilt "
              f"({len(rec_report['orphans_adopted'])} orphan blobs), "
              f"{len(rec_report['quarantined'])} quarantined, "
              f"{len(rec_report['lost'])} lost; "
              f"{len(adopted)} agents re-adopted: {adopted}")
        for aid in adopted:
            engine.wake(aid)
    engine.submit(args.prompt)
    engine.run(args.ticks)
    print("events:", *engine.history, sep="\n  ")
    rep = engine.memory_report()
    tiers, agents = rep["tiers"], rep["agents"]
    print(f"memory: weights {rep['weight_bytes']/1e6:.1f}MB shared across "
          f"{rep['n_agents']} agents; ctx/agent {rep['context_bytes_per_agent']/1e6:.2f}MB")
    print(f"tiers:  hot {tiers['hot_bytes']/1e6:.2f}MB (device) | "
          f"warm {tiers['warm_bytes']/1e6:.2f}MB (host, {tiers['n_warm']} agents) | "
          f"cold {tiers['cold_bytes']/1e6:.2f}MB (disk, {tiers['n_cold']} agents)")
    print(f"agents: {agents['registered']} registered, {agents['active']} active, "
          f"{agents['hibernated']} hibernated, {agents['lost']} lost")
    # resilience counters (ISSUE 8): all zeros on a healthy run — nonzero
    # values are the memory hierarchy degrading instead of crashing
    srep = engine.store.report()
    print(f"faults: {srep['stat_quarantined']} quarantined, "
          f"{srep['stat_wake_retries']} wake retries, "
          f"{srep['stat_recovered']} recovered, "
          f"{srep['stat_prefetch_errors']} prefetch errors, "
          f"{srep['stat_worker_respawns']} worker respawns; "
          f"engine: {engine.stats['wake_failures']} wake failures, "
          f"{engine.stats['lost_agents']} lost, "
          f"{engine.stats['recoveries']} recoveries")


if __name__ == "__main__":
    main()
