"""Serving launcher: the async front-end over either backend (ISSUE 9).

    # multi-tenant, streaming, weighted-fair — the cortex engine backend
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-0.5b --mode cortex \
        --tenants gold:4,free:1 \
        --request "gold:0:Question: what scales? [TASK: verify memory math] Answer:" \
        --request "free:0:Summarize the architecture."

    # plain continuous batching behind the same front-end
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --mode batch

    # the same request set over REAL sockets (ISSUE 10): an HTTP/1.1 + SSE
    # server fronts the frontend and each request becomes a loopback client
    PYTHONPATH=src python -m repro.launch.serve --mode batch --listen 127.0.0.1:8080

Requests stream: decoded chunks print as the backend commits them (bitwise
identical to the end-of-run decode — the incremental UTF-8 decoder), and a
final per-tenant SLO summary (TTFT, time-per-output-token, p50/p99 tick
latency, token shares, fairness counters) mirrors what
benchmarks/bench_serving.py records.

Crash recovery (ISSUE 8): point ``--cold-dir`` at a persistent directory
and a later run with ``--recover`` rebuilds the cold tier from disk
(integrity-checked; corrupt blobs quarantined) and re-adopts the agents it
finds — their streams continue bitwise where the dead process stopped.
``--wake-deadline`` bounds every tier promotion (engine ``wake`` and
server ``unpark``) so a stalled disk degrades to a counted failure
instead of a hang.
"""
from __future__ import annotations

import argparse
import threading

import jax

from repro.configs import get_config, list_archs
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.memory import SynapseStore
from repro.models import model as model_lib
from repro.serving.frontend import ServingFrontend
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer

DEFAULT_REQUESTS = [
    "gold:0:Question: what makes this system scale? [TASK: verify memory math] Answer:",
    "free:0:Summarize the warp-cortex architecture in one line.",
]


def parse_tenants(spec: str) -> dict[str, float]:
    """"gold:4,free:1" -> {"gold": 4.0, "free": 1.0}."""
    out = {}
    for part in spec.split(","):
        name, _, w = part.strip().partition(":")
        out[name] = float(w) if w else 1.0
    return out


def parse_request(spec: str) -> tuple[str, int, str]:
    """"tenant:priority:prompt" -> (tenant, priority, prompt); the prompt may
    itself contain colons."""
    tenant, _, rest = spec.partition(":")
    prio, _, prompt = rest.partition(":")
    return tenant, int(prio or 0), prompt


def _serve_over_sockets(fe, args, lock):
    """--listen mode (ISSUE 10): the same request set, but every request is
    a real loopback HTTP client reading an SSE stream — the summary metrics
    come back over ``GET /v1/metrics`` instead of the in-process handle."""
    from repro.serving.transport import SSEClient, TransportServer, http_json

    host, _, port = args.listen.partition(":")
    srv = TransportServer(fe, host or "127.0.0.1", int(port or 0))
    srv.start()
    print(f"listening on {srv.url} (POST /v1/generate, GET /v1/metrics, "
          f"POST /v1/cancel/<rid>)")

    def client(tenant, prio, prompt):
        c = SSEClient(srv.host, srv.port)
        try:
            status, _ = c.generate(prompt, tenant=tenant, priority=prio,
                                   max_new_tokens=args.max_new_tokens)
            if status != 200:
                with lock:
                    print(f"[{tenant}] HTTP {status}: {c.body_json()}")
                return
            rid, final = "?", {}
            for ev in c.events():
                if "rid" in ev:
                    rid = ev["rid"]
                elif "text" in ev and not args.no_stream:
                    with lock:
                        print(f"[{rid}/{tenant}] {ev['text']!r}")
                elif ev.get("done"):
                    final = ev
            with lock:
                print(f"[{rid}/{tenant}] <{final.get('status')}>")
        finally:
            c.close()

    clients = []
    for spec in args.request or DEFAULT_REQUESTS:
        tenant, prio, prompt = parse_request(spec)
        t = threading.Thread(target=client, args=(tenant, prio, prompt),
                             daemon=True)
        t.start()
        clients.append(t)
    for t in clients:
        t.join()
    code, m = http_json(srv.host, srv.port, "GET", "/v1/metrics")
    ts = dict(srv.stats)
    srv.stop()
    print(f"transport: {ts['http_requests']} http requests, "
          f"{ts['streams_ok']}/{ts['streams_opened']} streams ok, "
          f"{ts['rejected_429']} rejected (429), "
          f"{ts['disconnects']} disconnects")
    if code != 200:
        raise RuntimeError(f"GET /v1/metrics answered {code}")
    return m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-0.5b", choices=list_archs())
    ap.add_argument("--mode", default="cortex", choices=["cortex", "batch"])
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--tenants", default="gold:4,free:1",
                    help="weighted-fair tenant spec, e.g. 'gold:4,free:1'")
    ap.add_argument("--request", action="append", default=None,
                    metavar="TENANT:PRIORITY:PROMPT",
                    help="a request to serve (repeatable); higher priority "
                         "admits sooner within the starvation bound")
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--no-stream", action="store_true",
                    help="print only final texts instead of live chunks")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="serve the request set over real sockets: start the "
                         "HTTP/SSE transport there and drive each request "
                         "through a loopback client (port 0 = ephemeral)")
    ap.add_argument("--wake-deadline", type=float, default=None, metavar="SECONDS",
                    help="bound every cold->device promotion: engine wake() "
                         "and server unpark() fail observably past this")
    ap.add_argument("--cold-dir", default=None,
                    help="directory for the cold (disk) tier; enables --recover")
    ap.add_argument("--recover", action="store_true",
                    help="rebuild the cold tier from --cold-dir and re-adopt "
                         "the hibernated agents found there before serving")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    store = SynapseStore(cold_dir=args.cold_dir) if args.cold_dir else None
    tenants = parse_tenants(args.tenants)

    engine = None
    if args.mode == "batch":
        backend = BatchServer(params, cfg, tok, n_lanes=4, capacity=512,
                              sampling=SamplingParams(temperature=0.9),
                              wake_deadline_s=args.wake_deadline,
                              **({"store": store} if store else {}))
    else:
        engine = CortexEngine(Prism(params, cfg), tok, n_main=2, max_side=4,
                              main_capacity=512, side_max_steps=12, theta=-1.0,
                              sampling=SamplingParams(temperature=1.0),
                              wake_deadline_s=args.wake_deadline,
                              **({"store": store} if store else {}))
        if args.recover:
            if not args.cold_dir:
                ap.error("--recover requires --cold-dir")
            rec_report = engine.store.recover(args.cold_dir)
            adopted = engine.adopt_hibernated()
            print(f"recover: {len(rec_report['recovered'])} cold entries rebuilt "
                  f"({len(rec_report['orphans_adopted'])} orphan blobs), "
                  f"{len(rec_report['quarantined'])} quarantined, "
                  f"{len(rec_report['lost'])} lost; "
                  f"{len(adopted)} agents re-adopted: {adopted}")
            for aid in adopted:
                engine.wake(aid)
        backend = engine

    fe = ServingFrontend(backend, tenants=tenants,
                         default_max_new_tokens=args.max_new_tokens)
    lock = threading.Lock()  # interleaved chunk prints stay line-atomic

    if args.listen is not None:
        m = _serve_over_sockets(fe, args, lock)
    else:
        def pump(rid, tenant, stream):
            for chunk in stream:
                with lock:
                    print(f"[{rid}/{tenant}] {chunk!r}")
            with lock:
                print(f"[{rid}/{tenant}] <{stream.status}>")

        printers = []
        for spec in args.request or DEFAULT_REQUESTS:
            tenant, prio, prompt = parse_request(spec)
            s = fe.submit(prompt, tenant=tenant, priority=prio)
            if not args.no_stream:
                t = threading.Thread(target=pump, args=(s.rid, tenant, s),
                                     daemon=True)
                t.start()
                printers.append(t)
        fe.serve()
        for t in printers:
            t.join(timeout=10)
        m = fe.metrics()
    if args.no_stream:
        for rid, req in sorted(fe.requests.items()):
            print(f"[{rid}/{req.tenant}] <{req.status}> {req.stream.text!r}")
    print(f"\nserving: {m['completed']} completed | "
          f"ttft p50 {m['ttft_s']['p50']*1e3:.1f}ms p99 {m['ttft_s']['p99']*1e3:.1f}ms | "
          f"tick p50 {m['tick_latency_s']['p50']*1e3:.2f}ms "
          f"p99 {m['tick_latency_s']['p99']*1e3:.2f}ms")
    for name, t in m["tenants"].items():
        print(f"tenant {name}: weight {t['weight']:g}, share {t['token_share']:.2f} "
              f"({t['tokens_out']} toks), admitted {t['admitted']}, "
              f"rejected {t['rejected']}, ttft p50 {t['ttft_p50_s']*1e3:.1f}ms")
    f = m["fairness"]
    print(f"fairness: {f['admission_rounds']} admission rounds, "
          f"{f['starvation_promotions']} starvation promotions "
          f"(bound {f['starvation_rounds']})")

    if engine is not None:
        rep = engine.memory_report()
        tiers, agents = rep["tiers"], rep["agents"]
        print(f"memory: weights {rep['weight_bytes']/1e6:.1f}MB shared across "
              f"{rep['n_agents']} agents; ctx/agent {rep['context_bytes_per_agent']/1e6:.2f}MB")
        print(f"tiers:  hot {tiers['hot_bytes']/1e6:.2f}MB (device) | "
              f"warm {tiers['warm_bytes']/1e6:.2f}MB (host, {tiers['n_warm']} agents) | "
              f"cold {tiers['cold_bytes']/1e6:.2f}MB (disk, {tiers['n_cold']} agents)")
        print(f"agents: {agents['registered']} registered, {agents['active']} active, "
              f"{agents['hibernated']} hibernated, {agents['lost']} lost")
        # resilience counters (ISSUE 8): all zeros on a healthy run — nonzero
        # values are the memory hierarchy degrading instead of crashing
        srep = engine.store.report()
        print(f"faults: {srep['stat_quarantined']} quarantined, "
              f"{srep['stat_wake_retries']} wake retries, "
              f"{srep['stat_recovered']} recovered, "
              f"{srep['stat_prefetch_errors']} prefetch errors, "
              f"{srep['stat_worker_respawns']} worker respawns; "
              f"engine: {engine.stats['wake_failures']} wake failures, "
              f"{engine.stats['lost_agents']} lost, "
              f"{engine.stats['recoveries']} recoveries")


if __name__ == "__main__":
    main()
