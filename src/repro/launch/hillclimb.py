"""§Perf hillclimb driver: named variants per chosen pair, each re-lowered
and re-analyzed; results land in benchmarks/artifacts/hillclimb/.

The three chosen pairs (from the baseline roofline census):
  * qwen3-moe-30b-a3b x train_4k — worst roofline fraction (memory term 68x
    the compute term): the global MoE dispatch sort is SPMD-unshardable.
  * qwen1.5-110b x train_4k — most collective-bound (40s X vs 17s C):
    fp32 master weights are all-gathered, remat re-gathers in bwd.
  * qwen3-8b x long_500k — most representative of the paper's technique
    (synapse decode): per-token FSDP weight gathers dwarf the tiny synapse
    cache traffic.

    PYTHONPATH=src python -m repro.launch.hillclimb --pair moe|dense110|synapse
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse
import dataclasses

from repro.launch.roofline import analyze_pair

OUT = "benchmarks/artifacts/hillclimb"


def _cfgmod(**kw):
    return lambda cfg: dataclasses.replace(cfg, **kw)


# variant name -> (cfg_transform, fsdp_on)
CAMPAIGNS = {
    # ---- worst roofline fraction: MoE train ----
    "moe": (
        "qwen3-moe-30b-a3b",
        "train_4k",
        [
            ("baseline_global_dispatch", _cfgmod(moe_dispatch="global"), True),
            ("per_lane_dispatch", _cfgmod(moe_dispatch="per_lane"), True),
            ("per_lane+bf16_params", _cfgmod(moe_dispatch="per_lane", param_dtype="bfloat16"), True),
            ("per_lane+bf16+dots", _cfgmod(moe_dispatch="per_lane", param_dtype="bfloat16", remat_policy="dots"), True),
            # per-lane dispatch + batch-only activations: lane gathers stay
            # local (no seq-parallel all-gather of x inside the dispatch)
            ("per_lane+act_batch", _cfgmod(moe_dispatch="per_lane"), True, True, "batch"),
            ("per_lane+ep_pin+act_batch", _cfgmod(moe_dispatch="per_lane"), True, True, "batch"),
            ("global+act_batch", _cfgmod(moe_dispatch="global"), True, True, "batch"),
        ],
    ),
    # ---- most collective-bound: 110B dense train ----
    "dense110": (
        "qwen1.5-110b",
        "train_4k",
        [
            ("baseline_f32_master", None, True),
            ("bf16_params", _cfgmod(param_dtype="bfloat16"), True),
            ("bf16+remat_dots", _cfgmod(param_dtype="bfloat16", remat_policy="dots"), True),
            # act_mode batch: no sequence-parallel saves -> no per-layer
            # activation all-gathers (memory for collectives trade)
            ("act_batch_only", None, True, True, "batch"),
        ],
    ),
    # ---- paper's technique: synapse long-context decode ----
    "synapse": (
        "qwen3-8b",
        "long_500k",
        [
            ("baseline_fsdp_weights", None, True, True),
            ("tp_weights", None, False, True),
            ("tp_weights+bf16", _cfgmod(param_dtype="bfloat16"), False, True),
            ("replicated_synapse", None, True, False),
            ("replicated_synapse+tp+bf16", _cfgmod(param_dtype="bfloat16"), False, False),
            # onehot writes + shard_map flash-decode attend (synapse sharded)
            ("flashdecode_shardmap", None, True, True),
            ("flashdecode+bf16", _cfgmod(param_dtype="bfloat16"), True, True),
        ],
    ),
    # decode_32k sanity campaign (extra, cheap)
    "decode32": (
        "qwen3-8b",
        "decode_32k",
        [
            ("baseline_fsdp_weights", None, True),
            ("tp_weights", None, False),
        ],
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=list(CAMPAIGNS))
    ap.add_argument("--variant", default=None)
    args = ap.parse_args()
    arch, shape, variants = CAMPAIGNS[args.pair]
    for v in variants:
        name, transform, fsdp_on = v[0], v[1], v[2]
        syn_shard = v[3] if len(v) > 3 else True
        act_mode = v[4] if len(v) > 4 else "auto"
        if args.variant and name != args.variant:
            continue
        analyze_pair(
            arch, shape, OUT, cfg_transform=transform, fsdp_on=fsdp_on,
            synapse_token_shard=syn_shard, act_mode=act_mode, variant=name,
        )


if __name__ == "__main__":
    main()
