"""Production meshes (v5e): single-pod 16x16 and 2-pod 2x16x16.

A function, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

# hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for sharding unit tests (needs
    --xla_force_host_platform_device_count >= n_data*n_model)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
