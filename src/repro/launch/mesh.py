"""Production meshes (v5e): single-pod 16x16 and 2-pod 2x16x16.

A function, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

# hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for sharding unit tests (needs
    --xla_force_host_platform_device_count >= n_data*n_model)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


LANE_AXIS = "lane"


def make_lane_mesh(n_lanes: int | None = None, *, devices=None):
    """1-D ``lane`` mesh for the lane-sharded cortex engine: side-agent
    lanes are split over this axis, main-stream state replicates. Defaults
    to every visible device (force more on CPU with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    devs = list(devices) if devices is not None else jax.devices()
    n = len(devs) if n_lanes is None else n_lanes
    if n > len(devs):
        raise ValueError(f"make_lane_mesh: {n} lanes > {len(devs)} devices")
    return jax.make_mesh((n,), (LANE_AXIS,), devices=devs[:n])


def lane_axis(mesh) -> str | None:
    """The lane axis name when ``mesh`` carries one, else None."""
    return LANE_AXIS if mesh is not None and LANE_AXIS in mesh.axis_names else None


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
