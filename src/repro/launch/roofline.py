"""Roofline analysis (deliverable g).

Per (arch x shape) on the single-pod 16x16 mesh, derive:

    compute_s    = HLO_FLOPs_per_chip / 197e12          (v5e bf16 peak)
    memory_s     = HLO_bytes_per_chip / 819e9           (HBM BW)
    collective_s = collective_bytes_per_chip / 50e9     (ICI link BW)

Methodology: XLA's cost_analysis counts a `while` body once, so scanned
layer stacks are undercounted. We therefore compile each pair at TWO shallow
depths L1 < L2 (same groups/pattern), fit flops(L) = a + b.L (exact: the
program is linear in depth), and extrapolate to the full depth. Collective
bytes come from the HLO parser (which multiplies loop bodies by recovered
trip counts) at the same two depths, fitted the same way. MODEL_FLOPS =
6 * N_active * tokens cross-checks the fit.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline --all
    PYTHONPATH=src python -m repro.launch.roofline --arch qwen3-8b --shape train_4k
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse
import dataclasses
import json
import time

import jax

from repro.configs import get_config, list_archs
from repro.launch import sharding as shard_lib
from repro.launch import specs as specs_lib
from repro.launch.dryrun import build_lowerable, parse_collectives
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models.config import ModelConfig

CHIPS = 256  # single-pod roofline mesh


def depth_variant(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    """Shallow UNROLLED variant preserving the group pattern. Unrolling makes
    XLA's cost model see every layer (a scanned while body is counted once)."""
    kw: dict = {"n_layers": n_layers, "scan_layers": False}
    if cfg.is_moe and cfg.first_k_dense:
        kw["first_k_dense"] = min(cfg.first_k_dense, max(1, n_layers - 1))
    return dataclasses.replace(cfg, **kw)


def _depths(cfg: ModelConfig) -> tuple[int, int]:
    if cfg.shared_attn_every > 0:
        e = cfg.shared_attn_every
        return e, 2 * e  # 1 vs 2 shared invocations
    if cfg.is_moe and cfg.first_k_dense:
        return 2, 4
    return 1, 3


def _extract_cost(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(float(v) for k, v in cost.items() if k.startswith("bytes accessed"))
    coll = parse_collectives(compiled.as_text())
    return {"flops": flops, "bytes": byts, "coll": float(coll["total_bytes"])}


def _compile_cfg(cfg: ModelConfig, shape_name: str, mesh, *, fsdp_on: bool = True, synapse_token_shard: bool = True, act_mode: str = "auto"):
    """build_lowerable but with an explicit cfg (depth variants)."""
    import repro.launch.dryrun as dr
    import repro.configs as configs_mod

    # monkey-light: temporarily register the variant under a unique name
    orig_get = configs_mod.get_config
    try:
        configs_mod_get_config = lambda arch, reduced=False: cfg
        dr.get_config = configs_mod_get_config
        fn, args, in_specs, out_specs, plan = build_lowerable(
            cfg.name, shape_name, mesh, fsdp_on=fsdp_on,
            synapse_token_shard=synapse_token_shard, act_mode=act_mode,
        )
    finally:
        dr.get_config = orig_get
    if plan.skip:
        return None, plan
    with mesh:
        compiled = (
            jax.jit(
                fn,
                in_shardings=shard_lib.shardings_for(in_specs, mesh),
                out_shardings=shard_lib.shardings_for(out_specs, mesh),
            )
            .lower(*args)
            .compile()
        )
    return compiled, plan


def model_flops(cfg: ModelConfig, plan: specs_lib.ShapePlan) -> float:
    """Analytic MODEL_FLOPS (global, forward only unless train)."""
    n_active = cfg.active_param_count()
    if plan.kind == "train":
        tokens = plan.seq * plan.batch
        base = 6.0 * n_active * tokens  # fwd+bwd
        attn = 0.0
        if cfg.block_kind == "attn":
            attn = 3 * 2 * 2 * cfg.n_layers * plan.batch * plan.seq**2 * cfg.n_heads * cfg.d_head * 0.5
        return base + attn
    if plan.kind == "prefill":
        tokens = plan.seq * plan.batch
        base = 2.0 * n_active * tokens
        attn = 0.0
        if cfg.block_kind == "attn":
            attn = 2 * 2 * cfg.n_layers * plan.batch * plan.seq**2 * cfg.n_heads * cfg.d_head * 0.5
        return base + attn
    # decode: one token per lane
    base = 2.0 * n_active * plan.batch
    attn = 0.0
    if cfg.block_kind == "attn" and plan.cache_kind == "full":
        attn = 2 * 2 * cfg.n_layers * plan.batch * plan.seq * cfg.n_heads * cfg.d_head
    elif cfg.block_kind == "attn" and plan.cache_kind == "synapse":
        T = specs_lib.LONG_LANDMARKS + specs_lib.LONG_WINDOW + specs_lib.LONG_INJECT
        attn = 2 * 2 * cfg.n_layers * plan.batch * T * cfg.n_heads * cfg.d_head
    return base + attn


def model_bytes_floor(cfg: ModelConfig, plan: specs_lib.ShapePlan) -> float:
    """Global HBM-traffic lower bound per step: every weight byte is read
    once (bf16 compute copies), plus full KV/state cache read+write for
    decode, plus one read+write of the token activations per layer."""
    import jax.numpy as jnp
    from repro.models import model as model_lib

    wbytes = cfg.param_count() * 2  # bf16 compute copies
    if plan.kind == "train":
        wbytes = cfg.param_count() * (2 + 2 + 4 * 3)  # fwd+bwd reads + grad + adam m,v,p f32
    act = 0
    tokens = plan.seq * plan.batch if plan.kind != "decode" else plan.batch
    act = 2 * cfg.n_layers * tokens * cfg.d_model * 2  # stream in+out per layer, bf16
    cache = 0.0
    if plan.kind == "decode":
        spec = specs_lib.cache_spec_for(plan)
        caches = jax.eval_shape(lambda: model_lib.init_caches(cfg, plan.batch, spec))
        cache = sum(
            x.size * jnp.dtype(x.dtype).itemsize for x in jax.tree_util.tree_leaves(caches)
        )
    return float(wbytes + act + cache)


def analyze_pair(
    arch: str,
    shape_name: str,
    out_dir: str,
    *,
    cfg_transform=None,
    fsdp_on: bool = True,
    synapse_token_shard: bool = True,
    act_mode: str = "auto",
    variant: str = "baseline",
) -> dict:
    cfg_full = get_config(arch)
    if cfg_transform is not None:
        cfg_full = cfg_transform(cfg_full)
    plan = specs_lib.plan_for(cfg_full, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": "16x16", "variant": variant}
    if plan.skip:
        rec.update(status="SKIP", reason=plan.skip)
        return rec
    mesh = make_production_mesh(multi_pod=False)
    L1, L2 = _depths(cfg_full)
    t0 = time.time()
    costs = []
    for L in (L1, L2):
        compiled, p = _compile_cfg(
            depth_variant(cfg_full, L), shape_name, mesh,
            fsdp_on=fsdp_on, synapse_token_shard=synapse_token_shard, act_mode=act_mode,
        )
        costs.append(_extract_cost(compiled))
    # linear fit per metric, extrapolate to full depth
    Lf = cfg_full.n_layers
    per = {}
    for key in ("flops", "bytes", "coll"):
        b = (costs[1][key] - costs[0][key]) / (L2 - L1)
        a = costs[0][key] - b * L1
        per[key] = max(a + b * Lf, 0.0)
    # analytic floors: inner recurrences (rwkv time scan, mamba2 chunk scan,
    # attention chunk maps) still lower to while loops that XLA counts once;
    # MODEL_FLOPS and a params+cache byte floor catch the undercount.
    mf_global_early = model_flops(cfg_full, plan)
    floor_flops = mf_global_early / CHIPS
    floor_bytes = model_bytes_floor(cfg_full, plan) / CHIPS
    measured = dict(per)
    per["flops"] = max(per["flops"], floor_flops)
    per["bytes"] = max(per["bytes"], floor_bytes)
    compute_s = per["flops"] / PEAK_FLOPS_BF16
    memory_s = per["bytes"] / HBM_BW
    collective_s = per["coll"] / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    mf_global = model_flops(cfg_full, plan)
    mf_per_chip = mf_global / CHIPS
    useful = mf_per_chip / per["flops"] if per["flops"] else 0.0
    rec.update(
        status="OK",
        kind=plan.kind,
        cache_kind=plan.cache_kind,
        depths=[L1, L2],
        per_chip={k: per[k] for k in per},
        measured_per_chip=measured,
        floors={"flops": floor_flops, "bytes": floor_bytes},
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_per_chip=mf_per_chip,
        useful_flops_ratio=useful,
        wall_s=round(time.time() - t0, 1),
    )
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    with open(os.path.join(out_dir, f"{arch}__{shape_name}{suffix}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(
        f"[roofline] {variant:16s} {arch:20s} {shape_name:12s} "
        f"C {compute_s*1e3:9.3f}ms  M {memory_s*1e3:9.3f}ms  "
        f"X {collective_s*1e3:9.3f}ms  dom={dominant:10s} useful={useful:5.2f}"
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/roofline")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else [a for a in list_archs() if a != "qwen2.5-0.5b"]
    shapes = [args.shape] if args.shape else list(specs_lib.SHAPES)
    recs = []
    for a in archs:
        for s in shapes:
            try:
                recs.append(analyze_pair(a, s, args.out))
            except Exception as e:
                print(f"[roofline] {a} x {s}: FAIL {type(e).__name__}: {e}")
                recs.append({"arch": a, "shape": s, "status": "FAIL", "error": str(e)})
    ok = sum(r["status"] == "OK" for r in recs)
    print(f"[roofline] {ok} OK / {len(recs)}")


if __name__ == "__main__":
    main()
