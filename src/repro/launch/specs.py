"""Abstract input specs (ShapeDtypeStruct) for every (arch x input shape).

The four assigned shapes:
    train_4k     seq 4096,    global_batch 256   -> train_step
    prefill_32k  seq 32768,   global_batch 32    -> prefill_step
    decode_32k   seq 32768,   global_batch 128   -> serve_step (1 new token)
    long_500k    seq 524288,  global_batch 1     -> serve_step, synapse/SSM

Skips (DESIGN.md §4): encoder-only archs (hubert) have no decode shapes;
long_500k dense/vlm/moe runs ONLY via the synapse cache (the paper's
technique is what makes it sub-quadratic).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# decode budget appended to prefill capacity
DECODE_PAD = 0
# synapse geometry for long-context decode (dense archs)
LONG_LANDMARKS = 4096
LONG_WINDOW = 1024
LONG_INJECT = 128


@dataclass(frozen=True)
class ShapePlan:
    arch: str
    shape: str
    kind: str           # train | prefill | decode
    seq: int
    batch: int
    cache_kind: str     # full | synapse | none (ssm-only or train)
    skip: str = ""      # non-empty -> skipped, with reason


def plan_for(cfg: ModelConfig, shape_name: str) -> ShapePlan:
    info = SHAPES[shape_name]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    skip = ""
    cache_kind = "none"
    if kind == "decode":
        if cfg.is_encoder_only:
            skip = "encoder-only architecture: no autoregressive decode step"
        elif cfg.is_attention_free:
            cache_kind = "none"          # O(1) recurrent state
        elif shape_name == "long_500k":
            cache_kind = "synapse"       # paper's technique unlocks 500k
        else:
            cache_kind = "full"
    if kind == "prefill" and cfg.is_encoder_only:
        cache_kind = "none"              # encoder forward, no cache
    elif kind == "prefill":
        cache_kind = "full"
    return ShapePlan(cfg.name, shape_name, kind, seq, batch, cache_kind, skip)


def cache_spec_for(plan: ShapePlan) -> model_lib.CacheSpec:
    if plan.cache_kind == "synapse":
        return model_lib.CacheSpec(
            kind="synapse",
            n_landmarks=LONG_LANDMARKS,
            window=LONG_WINDOW,
            n_inject=LONG_INJECT,
        )
    return model_lib.CacheSpec(kind="full", capacity=plan.seq + DECODE_PAD)


def train_batch_specs(cfg: ModelConfig, seq: int, batch: int):
    i32 = jnp.int32
    f = jnp.dtype(cfg.compute_dtype)
    out = {"labels": jax.ShapeDtypeStruct((batch, seq), i32)}
    if cfg.embed_inputs:
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
    else:
        out["embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), f)
        if cfg.rope_kind == "mrope":
            out["positions"] = jax.ShapeDtypeStruct((batch, 3, seq), i32)
    return out


def prefill_input_specs(cfg: ModelConfig, seq: int, batch: int):
    i32 = jnp.int32
    f = jnp.dtype(cfg.compute_dtype)
    if cfg.embed_inputs:
        out = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
    else:
        out = {"embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), f)}
        if cfg.rope_kind == "mrope":
            out["positions"] = jax.ShapeDtypeStruct((batch, 3, seq), i32)
    return out


def decode_input_specs(cfg: ModelConfig, batch: int):
    i32 = jnp.int32
    out = {"tokens": jax.ShapeDtypeStruct((batch,), i32)}
    if cfg.rope_kind == "mrope":
        out["positions"] = jax.ShapeDtypeStruct((batch, 3), i32)
    else:
        out["positions"] = jax.ShapeDtypeStruct((batch,), i32)
    if not cfg.embed_inputs:
        # decode generates text tokens through the embed table — tokens input
        pass
    return out


def abstract_caches(cfg: ModelConfig, plan: ShapePlan):
    spec = cache_spec_for(plan)
    return jax.eval_shape(lambda: model_lib.init_caches(cfg, plan.batch, spec)), spec


def input_specs(cfg: ModelConfig, plan: ShapePlan):
    """Returns (args dict of ShapeDtypeStructs, cache_spec or None)."""
    if plan.kind == "train":
        return train_batch_specs(cfg, plan.seq, plan.batch), None
    if plan.kind == "prefill":
        return prefill_input_specs(cfg, plan.seq, plan.batch), (
            None if plan.cache_kind == "none" else cache_spec_for(plan)
        )
    return decode_input_specs(cfg, plan.batch), cache_spec_for(plan)
