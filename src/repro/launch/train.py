"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --seq 256 --batch 16 [--mesh debug|single|multi]

On this CPU container use the default --mesh debug (1 device) or reduced
configs; the single/multi meshes are the production targets (the dry-run
proves they lower+compile; real runs need the hardware).
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--mesh", default="debug", choices=["debug", "single", "multi"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    if args.mesh != "debug":
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
        ).strip()

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.checkpoint import io as ckpt
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, make_batch
    from repro.launch import sharding as shard_lib
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as model_lib
    from repro.training.optimizer import AdamWConfig
    from repro.training.trainer import init_train_state, make_train_step

    cfg = get_config(args.arch, reduced=args.reduced)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1), total_steps=args.steps)
    step_fn = make_train_step(cfg, opt)

    if args.mesh == "debug":
        step = jax.jit(step_fn)
        state = init_train_state(jax.random.key(0), cfg)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        model_lib.set_activation_sharding(P(dp, "model", None))
        state = init_train_state(jax.random.key(0), cfg)
        state_spec = shard_lib.param_specs(state, cfg, mesh)
        with mesh:
            state = jax.device_put(state, shard_lib.shardings_for(state_spec, mesh))
            step = jax.jit(
                step_fn,
                in_shardings=(shard_lib.shardings_for(state_spec, mesh), None),
                out_shardings=(shard_lib.shardings_for(state_spec, mesh), None),
            )

    for i in range(args.steps):
        batch = {
            k: jnp.asarray(v)
            for k, v in make_batch(cfg, DataConfig(seq_len=args.seq, batch_size=args.batch, seed=i)).items()
        }
        state, m = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(m['loss']):.4f}  lr {float(m['lr']):.3e}")
        if args.ckpt_every and i and i % args.ckpt_every == 0:
            ckpt.save(os.path.join(args.ckpt_dir, f"step{i}.msgpack.zst"), state.params)


if __name__ == "__main__":
    main()
