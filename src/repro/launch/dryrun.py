"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production meshes, record memory/cost analysis + collective bytes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

This is the ONLY entry point that forces 512 host devices (the two lines
below run before any other import, per the multi-pod dry-run contract);
smoke tests and benches see the real single CPU device.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse
import dataclasses
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.core import synapse_sharded
from repro.launch import sharding as shard_lib
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import abstract_train_state, make_train_step

SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over all array shapes found in an HLO type string."""
    total = 0
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> its body lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        ls = line.rstrip()
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{\s*$", ls)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if ls.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(ls.strip())
    return comps


def parse_collectives(hlo_text: str) -> dict:
    """Collective output bytes with while-loop trip-count attribution.

    Computations form a call graph; while-op bodies get multiplier =
    caller_mult * trip_count, where the trip count is recovered from the
    loop condition's comparison constant (scan loops always have one).
    """
    comps = _split_computations(hlo_text)

    # per-computation: collectives, while-calls (body, cond), other calls
    coll: dict[str, list[tuple[str, int]]] = {}
    whiles: dict[str, list[tuple[str, str]]] = {}
    calls: dict[str, list[str]] = {}
    for name, lines in comps.items():
        for ls in lines:
            if "=" not in ls:
                continue
            rhs = ls.split("=", 1)[1]
            for kind in _COLL_KINDS:
                if re.search(rf"\b{kind}(?:-start)?\(", rhs):
                    b = _shape_bytes(rhs.split(kind)[0])
                    coll.setdefault(name, []).append((kind, b))
                    break
            wm = re.search(r"\bwhile\(.*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)", rhs)
            if not wm:
                wm2 = re.search(r"\bwhile\(.*?body=%?([\w.\-]+).*?condition=%?([\w.\-]+)", rhs)
                if wm2:
                    whiles.setdefault(name, []).append((wm2.group(1), wm2.group(2)))
            else:
                whiles.setdefault(name, []).append((wm.group(2), wm.group(1)))
            for cm in re.finditer(r"(?:calls|to_apply|fusion)=%?([\w.\-]+)", rhs):
                calls.setdefault(name, []).append(cm.group(1))

    def trip_count(cond_name: str) -> int:
        consts = []
        for ls in comps.get(cond_name, []):
            for c in re.finditer(r"constant\((\d+)\)", ls):
                consts.append(int(c.group(1)))
        return max(consts) if consts else 1

    # propagate multipliers from ENTRY
    entry = next((n for n in comps if "main" in n or n.startswith("entry")), None)
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n])) if comps else None
    mult: dict[str, int] = {}

    def visit(name: str, m: int):
        if m <= mult.get(name, 0):
            return
        mult[name] = m
        for body, cond in whiles.get(name, []):
            visit(body, m * max(trip_count(cond), 1))
            visit(cond, m)
        for callee in calls.get(name, []):
            visit(callee, m)

    if entry:
        visit(entry, 1)

    per_kind: dict[str, int] = {}
    total_once = 0
    total = 0
    for name, ops in coll.items():
        m = mult.get(name, 1)
        for kind, b in ops:
            per_kind[kind] = per_kind.get(kind, 0) + b * m
            total_once += b
            total += b * m
    return {"per_kind": per_kind, "total_bytes_once": total_once, "total_bytes": total}


def while_trip_counts_from_config(cfg) -> int:
    return cfg.n_layers


def build_lowerable(arch: str, shape_name: str, mesh, *, act_mode: str = "auto", fsdp_on: bool = True, synapse_token_shard: bool = True):
    """Returns (fn, args, in_shardings, out_shardings, plan).

    act_mode: "auto" -> sequence-parallel saves for full-seq kinds, batch-only
    for decode; "batch" -> batch-only; "off" -> no activation constraints.
    """
    cfg = get_config(arch)
    plan = specs_lib.plan_for(cfg, shape_name)
    if plan.skip:
        return None, None, None, None, plan
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if act_mode == "off":
        model_lib.set_activation_sharding(None)
    elif plan.kind == "decode" or act_mode == "batch":
        model_lib.set_activation_sharding(P(dp, None, None))
    else:
        # sequence-parallel layer-boundary saves (Megatron-SP style)
        model_lib.set_activation_sharding(P(dp, "model", None))
    # flash-decode shard_map attend over token-sharded synapse buffers: the
    # scoped token_sharding context must be LIVE while the fn traces (the
    # jit.lower call happens in run_one), so wrap rather than set globally
    tok_axis = "model" if (plan.cache_kind == "synapse" and synapse_token_shard) else None

    def _scoped(fn):
        @functools.wraps(fn)
        def wrapped(*a, **k):
            with synapse_sharded.token_sharding(tok_axis, mesh=mesh):
                return fn(*a, **k)

        return wrapped

    if plan.kind == "train":
        state_abs = abstract_train_state(cfg)
        batch_abs = specs_lib.train_batch_specs(cfg, plan.seq, plan.batch)
        state_spec = shard_lib.param_specs(state_abs, cfg, mesh, fsdp_on=fsdp_on)
        batch_spec = shard_lib.batch_specs(batch_abs, cfg, mesh)
        opt_cfg = AdamWConfig()
        step_fn = _scoped(make_train_step(cfg, opt_cfg))
        out_spec = (state_spec, jax.tree.map(lambda _: P(), {
            "loss": 0, "ce": 0, "lb_loss": 0, "drop_frac": 0, "grad_norm": 0, "lr": 0}))
        return step_fn, (state_abs, batch_abs), (state_spec, batch_spec), out_spec, plan

    params_abs = model_lib.abstract_params(cfg)
    params_spec = shard_lib.param_specs(params_abs, cfg, mesh, fsdp_on=fsdp_on)

    if plan.kind == "prefill":
        inputs_abs, cache_spec = specs_lib.input_specs(cfg, plan)
        inputs_spec = shard_lib.batch_specs(inputs_abs, cfg, mesh)
        if cfg.is_encoder_only:
            fn = _scoped(lambda p, i: model_lib.forward(p, cfg, i))
            out = (params_spec, inputs_spec)
            return fn, (params_abs, inputs_abs), out, (P(), {"lb_loss": P(), "drop_frac": P(), "hidden_last": P()}), plan
        caches_abs = jax.eval_shape(lambda: model_lib.init_caches(cfg, plan.batch, cache_spec))
        caches_spec = shard_lib.cache_specs(caches_abs, cfg, mesh, synapse_token_shard=synapse_token_shard)
        fn = _scoped(lambda p, i, c: model_lib.prefill(p, cfg, i, c, spec=cache_spec))
        out_spec = (
            shard_lib.fit_spec(mesh, (plan.batch, cfg.vocab_size), [dp, None]),
            shard_lib.fit_spec(mesh, (plan.batch, cfg.d_model), [dp, None]),
            caches_spec,
        )  # logits, hidden, caches
        return (
            fn,
            (params_abs, inputs_abs, caches_abs),
            (params_spec, inputs_spec, caches_spec),
            out_spec,
            plan,
        )

    # decode
    inputs_abs, cache_spec = specs_lib.input_specs(cfg, plan)
    inputs_spec = shard_lib.batch_specs(inputs_abs, cfg, mesh)
    caches_abs = jax.eval_shape(lambda: model_lib.init_caches(cfg, plan.batch, cache_spec))
    caches_spec = shard_lib.cache_specs(caches_abs, cfg, mesh, synapse_token_shard=synapse_token_shard)
    fn = _scoped(lambda p, i, c: model_lib.decode_step(p, cfg, i, c, spec=cache_spec))
    out_spec = (
        shard_lib.fit_spec(mesh, (plan.batch, cfg.vocab_size), [dp, None]),
        shard_lib.fit_spec(mesh, (plan.batch, cfg.d_model), [dp, None]),
        caches_spec,
    )  # logits, hidden, caches
    return (
        fn,
        (params_abs, inputs_abs, caches_abs),
        (params_spec, inputs_spec, caches_spec),
        out_spec,
        plan,
    )


def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str | None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    t0 = time.time()
    try:
        fn, args, in_specs, out_specs, plan = build_lowerable(arch, shape_name, mesh)
        if plan.skip:
            rec.update(status="SKIP", reason=plan.skip)
            print(f"[dryrun] {arch} x {shape_name} on {mesh_name}: SKIP ({plan.skip})")
            if out_dir:
                os.makedirs(out_dir, exist_ok=True)
                with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json"), "w") as f:
                    json.dump(rec, f, indent=1, default=str)
            return rec
        with mesh:
            in_sh = shard_lib.shardings_for(in_specs, mesh)
            out_sh = shard_lib.shardings_for(out_specs, mesh)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        rec.update(
            status="OK",
            kind=plan.kind,
            cache_kind=plan.cache_kind,
            seq=plan.seq,
            batch=plan.batch,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=_mem_dict(mem),
            cost={k: v for k, v in (cost or {}).items() if isinstance(v, (int, float))},
            collectives=coll,
            hlo_bytes=len(hlo),
        )
        print(
            f"[dryrun] {arch} x {shape_name} on {mesh_name}: OK "
            f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
            f"argbytes/dev {rec['memory'].get('argument_size_in_bytes', 0)/1e9:.2f}GB, "
            f"temp/dev {rec['memory'].get('temp_size_in_bytes', 0)/1e9:.2f}GB)"
        )
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}", traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} x {shape_name} on {mesh_name}: FAIL {type(e).__name__}: {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def run_lane(n_side: int, *, n_devices: int = 8, sync_every: int = 8,
             out_dir: str | None = None) -> dict:
    """Abstract lower + compile of the LANE-SHARDED macro tick (ISSUE 6).

    Builds the exact TickState the engine would hold at ``max_side=n_side``
    via ``jax.eval_shape`` (no buffers materialize — this is how the
    1024-lane shape dry-runs on the container), wraps the fused window in
    ``shard_map`` over a lane mesh, and records memory/collective analysis.
    """
    from repro.core import engine as engine_lib
    from repro.launch.mesh import make_lane_mesh
    from repro.serving.sampler import SamplingParams

    cfg = get_config("qwen2.5-0.5b", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    jcfg = dataclasses.replace(cfg, scan_layers=cfg.scan_layers and cfg.n_layers > 8)
    mesh = make_lane_mesh(n_devices)
    main_spec = model_lib.CacheSpec(kind="full", capacity=128)
    side_spec = model_lib.CacheSpec(kind="synapse", n_landmarks=64, window=64, n_inject=16)
    side_spec = dataclasses.replace(
        side_spec,
        policy=dataclasses.replace(side_spec.policy, attend_impl="piece"),
    )
    greedy = SamplingParams(greedy=True)
    state_abs = jax.eval_shape(
        lambda: engine_lib.init_tick_state(
            cfg, n_main=1, max_side=n_side, main_spec=main_spec,
            side_spec=side_spec, ring_capacity=sync_every, side_prompt_cap=64,
            main_sampling=greedy, side_sampling=greedy,
        )
    )
    params_abs = model_lib.abstract_params(cfg)
    specs = shard_lib.tick_state_specs(state_abs, mesh)
    fn = synapse_sharded.shard_map_nocheck(
        functools.partial(
            engine_lib.fused_tick, cfg=jcfg, main_spec=main_spec,
            side_spec=side_spec, step_sides=True, use_filters=False,
            any_greedy=True, n_ticks=sync_every,
        ),
        mesh, in_specs=(P(), specs), out_specs=specs,
    )
    rec: dict = {"kind": "lane_macro_tick", "n_side": n_side,
                 "lane_mesh_shape": list(mesh.devices.shape),
                 "sync_every": sync_every}
    t0 = time.time()
    try:
        jitted = jax.jit(fn, donate_argnums=(1,))
        lowered = jitted.lower(params_abs, state_abs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        rec.update(
            status="OK", lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=_mem_dict(mem), collectives=parse_collectives(hlo),
            hlo_bytes=len(hlo),
        )
        print(
            f"[dryrun] lane macro tick n_side={n_side} on {n_devices}-device "
            f"lane mesh: OK (lower {t_lower:.1f}s compile {t_compile:.1f}s, "
            f"argbytes/dev {rec['memory'].get('argument_size_in_bytes', 0)/1e9:.2f}GB)"
        )
    except Exception as e:
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] lane macro tick n_side={n_side}: FAIL {type(e).__name__}: {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"lane__s{n_side}__d{n_devices}.json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def run_registry(n_registered: int, *, arch: str = "qwen2.5-0.5b",
                 n_active: int = 8, main_capacity: int = 1024,
                 out_dir: str | None = None) -> dict:
    """Abstract tiered-memory accounting (ISSUE 7): what ``n_registered``
    agents cost when only ``n_active`` hold device lanes.

    Everything is ``eval_shape`` — the per-agent snapshot is the exact
    pytree `CortexEngine.hibernate` gathers (`_gather_main_lane` over the
    abstract TickState), so the bytes are the real hibernation payload at
    full `main_capacity`, computed without materializing a single buffer.
    The same math extrapolated to 1M agents is the paper's capacity claim:
    device cost is flat in ``n_registered`` (weights + active lanes only);
    dormant agents ride host RAM and zstd disk. The zstd ratio, when the
    codec is installed, is measured on synthetic float32 noise — a LOWER
    bound (real KV activations compress better than noise)."""
    import math

    from repro.checkpoint import io as ckpt_io
    from repro.core import engine as engine_lib
    from repro.serving.sampler import SamplingParams

    cfg = get_config(arch)
    main_spec = model_lib.CacheSpec(kind="full", capacity=main_capacity)
    side_spec = model_lib.CacheSpec(
        kind="synapse", n_landmarks=64, window=64, n_inject=16
    )
    greedy = SamplingParams(greedy=True)
    state_abs = jax.eval_shape(
        lambda: engine_lib.init_tick_state(
            cfg, n_main=n_active, max_side=8, main_spec=main_spec,
            side_spec=side_spec, ring_capacity=8, side_prompt_cap=64,
            main_sampling=greedy, side_sampling=greedy,
        )
    )
    snap_abs = jax.eval_shape(engine_lib._gather_main_lane, state_abs, 0)

    def abs_bytes(tree) -> int:
        return sum(
            math.prod(x.shape) * x.dtype.itemsize
            for x in jax.tree_util.tree_leaves(tree)
        )

    per_agent = abs_bytes(snap_abs)
    weight_bytes = abs_bytes(model_lib.abstract_params(cfg))

    zstd_ratio = None
    if ckpt_io.zstandard is not None:
        import numpy as np

        rng = np.random.default_rng(0)
        noise = jax.tree_util.tree_map(
            lambda s: rng.standard_normal(s.shape).astype(s.dtype)
            if s.dtype.kind == "f"
            else rng.integers(0, 2, s.shape).astype(s.dtype),
            snap_abs,
        )
        blob = ckpt_io.dumps(noise)
        zstd_ratio = per_agent / len(blob)

    def tier_table(n: int) -> dict:
        dormant = max(0, n - n_active)
        warm = dormant * per_agent
        return {
            "n_registered": n,
            "device_bytes": weight_bytes + n_active * per_agent,
            "warm_bytes_all_host": warm,
            "cold_bytes_all_disk": (
                int(warm / zstd_ratio) if zstd_ratio else None
            ),
            "device_bytes_if_all_resident": weight_bytes + n * per_agent,
        }

    rec = {
        "kind": "registry_tiers",
        "arch": arch,
        "n_active": n_active,
        "main_capacity": main_capacity,
        "per_agent_snapshot_bytes": per_agent,
        "weight_bytes": weight_bytes,
        "zstd_ratio_noise_floor": zstd_ratio,
        "at_n": tier_table(n_registered),
        "at_1m": tier_table(1_000_000),
    }
    t = rec["at_n"]
    print(
        f"[dryrun] registry {arch}: {n_registered} registered / {n_active} "
        f"active @ capacity {main_capacity}: snapshot/agent "
        f"{per_agent/1e6:.2f}MB; device {t['device_bytes']/1e9:.2f}GB "
        f"(vs {t['device_bytes_if_all_resident']/1e9:.2f}GB all-resident), "
        f"host {t['warm_bytes_all_host']/1e9:.2f}GB"
        + (
            f", disk {t['cold_bytes_all_disk']/1e9:.2f}GB "
            f"(zstd ratio >= {zstd_ratio:.2f})"
            if zstd_ratio
            else " (zstd unavailable: cold tier sized as None)"
        )
    )
    m = rec["at_1m"]
    print(
        f"[dryrun] registry {arch}: extrapolated 1M agents: device "
        f"{m['device_bytes']/1e9:.2f}GB flat, host+disk spill "
        f"{m['warm_bytes_all_host']/1e12:.2f}TB raw — vs "
        f"{m['device_bytes_if_all_resident']/1e12:.2f}TB if all resident"
    )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"registry__{arch}__{n_registered}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out and isinstance(mem, str):
        out["raw"] = mem[:2000]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(specs_lib.SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--lane", type=int, default=None, metavar="N_SIDE",
                    help="lower+compile the lane-sharded macro tick at N_SIDE "
                         "side lanes on an 8-device lane mesh (ISSUE 6 scale "
                         "dry-run; e.g. --lane 1024)")
    ap.add_argument("--registry", type=int, default=None, metavar="N",
                    help="abstract tiered-memory accounting for N registered "
                         "agents over --registry-active lanes (ISSUE 7; e.g. "
                         "--registry 10000), incl. the 1M-agent extrapolation")
    ap.add_argument("--registry-active", type=int, default=8)
    args = ap.parse_args()

    if args.registry is not None:
        run_registry(args.registry, arch=args.arch or "qwen2.5-0.5b",
                     n_active=args.registry_active, out_dir=args.out)
        return

    if args.lane is not None:
        rec = run_lane(args.lane, out_dir=args.out)
        if rec["status"] != "OK":
            raise SystemExit(1)
        return

    combos = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    archs = [a for a in archs if a != "qwen2.5-0.5b" or args.arch == a]
    shapes = list(specs_lib.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                combos.append((arch, shape, mp))

    results = [run_one(a, s, multi_pod=mp, out_dir=args.out) for a, s, mp in combos]
    ok = sum(r["status"] == "OK" for r in results)
    skip = sum(r["status"] == "SKIP" for r in results)
    fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n[dryrun] {ok} OK, {skip} SKIP, {fail} FAIL / {len(results)} combos")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
