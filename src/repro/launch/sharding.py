"""Sharding rules: PartitionSpec trees for params, optimizer state, batches
and caches, with divisibility-aware fallback.

Baseline scheme (hillclimbed in EXPERIMENTS.md §Perf):
  * FSDP over the ("pod","data") axes on the input dim of every matrix,
  * tensor parallel over "model" on the heads/ffn/expert dim,
  * experts sharded over "model" (expert parallelism),
  * batch over ("pod","data"); full-KV capacity dim over "model" when the
    kv-head count does not divide the model axis.
Any axis that does not divide a dimension is dropped (replicated) — the spec
builder never produces an invalid sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, axes):
    """Return `axes` if it divides dim, trying progressively smaller subsets."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    for k in range(len(axes), 0, -1):
        cand = axes[-k:]  # prefer keeping the last (usually 'data'/'model')
        if dim % _axis_size(mesh, cand) == 0:
            return cand if len(cand) > 1 else cand[0]
    return None


def _spec(mesh: Mesh, shape, axes_per_dim) -> P:
    out = []
    for dim, ax in zip(shape, axes_per_dim):
        out.append(_fit(mesh, dim, ax))
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules (path- and shape-based)
# ---------------------------------------------------------------------------
_IN_OUT = {"wq", "wk", "wv", "gate", "up", "w_in", "wuq", "wuk", "wuv", "wdkv",
           "wdq", "head", "wr", "wg", "embed_proj"}
_OUT_IN = {"wo", "down", "w_out"}


def _param_rule(path_keys: list[str], shape, fsdp, tp):
    name = path_keys[-1]
    nd = len(shape)
    stacked = "groups" in path_keys  # leading layer-stack dim
    off = 1 if stacked and nd >= 2 else 0
    lead = [None] * off
    body = shape[off:]
    bnd = len(body)

    if name == "embed":
        return lead + [tp, None]
    if bnd == 0 or bnd == 1:
        return lead + [None] * bnd
    if name in ("experts_gate", "experts_up"):  # [E, dm, ff]
        return lead + [tp, fsdp, None]
    if name in ("experts_down",):               # [E, ff, dm]
        return lead + [tp, None, fsdp]
    if name == "router":
        return lead + [fsdp, None]
    if name == "lora_a":                        # [n_inv, dm, r]
        return lead + [None, fsdp, None]
    if name == "lora_b":                        # [n_inv, r, out]
        return lead + [None, None, tp]
    if name == "conv_w":                        # [W, channels]
        return lead + [None, tp]
    if name == "u":                             # [h, hs]
        return lead + [tp, None]
    if name in ("mu", "mix_a", "mix_b"):        # rwkv stacked small
        return lead + [None] * bnd
    if name in _OUT_IN and bnd == 2:
        return lead + [tp, fsdp]
    if bnd == 2:
        # default in->out matrices (_IN_OUT + decay_a/decay_b/cmix wk ...)
        return lead + [fsdp, tp]
    return lead + [None] * bnd


def _path_names(path) -> list[str]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return names


def param_specs(abstract_params, cfg: ModelConfig, mesh: Mesh, *, fsdp_on: bool = True):
    """PartitionSpec tree matching any params/opt-state pytree.

    fsdp_on=False: pure tensor-parallel weights (replicated over pod/data) —
    the serving-optimized mode (§Perf: kills per-step weight all-gathers).
    """
    fsdp = tuple(a for a in mesh.axis_names if a in ("pod", "data")) if fsdp_on else ()
    tp = "model"

    def one(path, leaf):
        names = _path_names(path)
        # disambiguate expert weights (experts/{gate,up,down})
        if len(names) >= 2 and names[-2] == "experts":
            names = names[:-1] + [f"experts_{names[-1]}"]
        axes = _param_rule(names, leaf.shape, fsdp, tp)
        return _spec(mesh, leaf.shape, axes)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def fit_spec(mesh: Mesh, shape, axes_per_dim) -> P:
    """Public divisibility-aware spec builder."""
    return _spec(mesh, shape, axes_per_dim)


def shardings_for(tree_of_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# lane-sharded engine state (ISSUE 6: the cortex macro tick under shard_map)
# ---------------------------------------------------------------------------
LANE_AXIS = "lane"


def tick_state_specs(state, mesh: Mesh, *, axis: str = LANE_AXIS):
    """PartitionSpec tree for the engine's :class:`TickState` on a lane mesh.

    Placement rule (the whole refactor in one function): every ``side_*``
    leaf shards its LANE dim over ``axis`` — dim 1 for the stacked
    ``side_caches`` ([L, S, ...]), dim 0 for everything else ([S] budgets,
    [S, R] token rings, [S, P] prompt buffers, [S, d] hidden, the
    LaneSampling arrays) — while main-stream state, the PRNG key, and the
    ring cursor replicate (every device runs the river redundantly; the
    paper's one-river/many-streams topology makes the river the cheap
    part). A lane count the axis does not divide replicates that leaf
    instead of producing an invalid sharding (same ``_fit`` contract as
    the param rules).
    """
    size = mesh.shape[axis]

    def one(path, leaf):
        names = _path_names(path)
        field = names[0] if names else ""
        if not field.startswith("side_"):
            return P()
        lane_dim = 1 if field == "side_caches" else 0
        ndim = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        axes = [None] * ndim
        if ndim > lane_dim and shape[lane_dim] % size == 0:
            axes[lane_dim] = axis
        return P(*axes)

    return jax.tree_util.tree_map_with_path(one, state)


def lane_cache_specs(caches, mesh: Mesh, *, axis: str = LANE_AXIS):
    """Stacked [L, B, ...] cache tree with the BATCH dim (dim 1) sharded
    over the lane axis — the BatchServer's lane placement (one KV lane per
    request, lanes spread across the mesh). Non-divisible lane counts
    replicate, like everywhere else."""
    size = mesh.shape[axis]

    def one(leaf):
        ndim = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        axes = [None] * ndim
        if ndim > 1 and shape[1] % size == 0:
            axes[1] = axis
        return P(*axes)

    return jax.tree.map(one, caches)


# ---------------------------------------------------------------------------
# lane gather/scatter (ISSUE 7: hibernate/wake one lane of a sharded state)
# ---------------------------------------------------------------------------
def lane_gather(tree, lane, *, axis: int = 1):
    """Slice ONE lane (keepdim) out of every leaf of a stacked cache tree.

    The demote half of hibernation: under jit with replicated
    ``out_shardings`` this is the gather that pulls a lane's leaves off a
    lane-sharded mesh (GSPMD inserts the collective); on one device it is
    a plain dynamic slice. `lane` may be traced.
    """
    def one(a):
        return jax.lax.dynamic_slice_in_dim(a, lane, 1, axis=axis)

    return jax.tree.map(one, tree)


def lane_scatter(tree, part, lane, *, axis: int = 1):
    """Write a one-lane slice (from :func:`lane_gather`) back into the full
    stacked tree at `lane` — the promote half of a wake. Casts each leaf to
    the destination dtype (snapshots are stored bitwise in the compute
    dtype, so this is a no-op cast in practice) and, under jit with the
    state's ``out_shardings``, re-shards onto the lane mesh."""
    def one(full, piece):
        return jax.lax.dynamic_update_slice_in_dim(
            full, piece.astype(full.dtype), lane, axis=axis
        )

    return jax.tree.map(one, tree, part)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------
def batch_specs(batch_abstract, cfg: ModelConfig, mesh: Mesh):
    """tokens/labels [B,S] and embeds [B,S,d] shard batch over (pod, data)."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    def one(path, leaf):
        axes = [dp] + [None] * (leaf.ndim - 1)
        return _spec(mesh, leaf.shape, axes)

    return jax.tree_util.tree_map_with_path(one, batch_abstract)


def cache_specs(caches_abstract, cfg: ModelConfig, mesh: Mesh, *, synapse_token_shard: bool = True):
    """Stacked caches [L, B, T, Hkv, D] (or state trees [L, B, ...]).

    Batch over (pod, data). For 4D+ cache leaves: try kv-heads over "model";
    if not divisible the _fit fallback replicates, and instead the token/
    capacity dim takes "model" (flash-decode style sharded KV).

    synapse_token_shard=False: landmark/window/inject buffers replicate their
    token dim (they are O(K+W+J) small; sharding it forces a per-step
    all-gather of every synapse buffer — §Perf hillclimb finding).
    """
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    tp = "model"
    tp_size = mesh.shape[tp]

    def one(path, leaf):
        nd = leaf.ndim
        shape = leaf.shape
        names = _path_names(path)
        is_synapse_buf = any(
            str(n).startswith(("lm_", "win_", "inj_")) for n in names
        )
        if is_synapse_buf and not synapse_token_shard:
            axes = [None, dp] + [None] * max(nd - 2, 0)
            if nd == 5 and shape[3] % tp_size == 0:
                axes[3] = tp
            return _spec(mesh, shape, axes[:nd])
        if nd <= 1:
            return P()
        if nd == 2:  # [L, B] lengths/counts
            return _spec(mesh, shape, [None, dp])
        if nd == 3:  # [L, B, T] pos/score  or [L, B, d] shift states
            return _spec(mesh, shape, [None, dp, None])
        if nd >= 4:
            # [L, B, T, Hkv, D] kv   | [L, B, nh, dh, ds] ssm | [L,B,H,hs,hs]
            head_dim_idx = 3 if nd == 5 else 2
            head = shape[head_dim_idx] if nd == 5 else shape[2]
            axes = [None, dp] + [None] * (nd - 2)
            if nd == 5 and shape[3] % tp_size == 0:
                axes[3] = tp            # kv heads over model
            elif nd == 5 and shape[2] % tp_size == 0:
                axes[2] = tp            # capacity over model (flash-decode)
            elif nd == 4 and shape[2] % tp_size == 0:
                axes[2] = tp            # latent capacity / ssm heads over model
            elif nd == 4 and shape[3] % tp_size == 0:
                axes[3] = tp            # channels over model (conv tails etc.)
            return _spec(mesh, shape, axes)
        return P()

    return jax.tree_util.tree_map_with_path(one, caches_abstract)
