"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts produced by launch/dryrun.py and launch/roofline.py.

    PYTHONPATH=src python -m repro.launch.report > /tmp/report.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = "benchmarks/artifacts/dryrun"
ROOFLINE_DIR = "benchmarks/artifacts/roofline"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(dirname):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fix_hint(rec) -> str:
    dom = rec["dominant"]
    kind = rec["kind"]
    if dom == "collective":
        if kind == "train":
            return "overlap FSDP all-gathers with layer compute / shrink seq-parallel gathers"
        return "replicate weights over data axis (kill per-step FSDP gathers) or widen TP"
    if dom == "memory":
        if kind == "decode":
            return "cache is the traffic: shrink KV (synapse/MLA) or widen batch to amortize weights"
        return "bigger per-chip batch or fuse ops to cut re-read traffic"
    return "compute-bound: at roofline; gains only from sparsity/quantization"


def dryrun_tables() -> str:
    recs = _load(DRYRUN_DIR)
    out = ["### Dry-run matrix (lower + compile)\n"]
    for mesh in ("16x16", "2x16x16"):
        rows = [r for r in recs if r.get("mesh") == mesh]
        if not rows:
            continue
        chips = 256 if mesh == "16x16" else 512
        out.append(f"\n**Mesh {mesh} ({chips} chips)** — {sum(r['status']=='OK' for r in rows)} OK, "
                   f"{sum(r['status']=='SKIP' for r in rows)} SKIP, "
                   f"{sum(r['status']=='FAIL' for r in rows)} FAIL\n")
        out.append("| arch | shape | status | kind | cache | args/dev GB | temp/dev GB | coll GB/step | compile s |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))
        for r in sorted(rows, key=key):
            if r["status"] == "SKIP":
                out.append(f"| {r['arch']} | {r['shape']} | SKIP — {r['reason'][:40]} | | | | | | |")
                continue
            if r["status"] == "FAIL":
                out.append(f"| {r['arch']} | {r['shape']} | FAIL {r.get('error','')[:40]} | | | | | | |")
                continue
            mem = r["memory"]
            coll = r["collectives"]["total_bytes"] / 1e9
            out.append(
                f"| {r['arch']} | {r['shape']} | OK | {r['kind']} | {r.get('cache_kind','')} "
                f"| {mem.get('argument_size_in_bytes',0)/1e9:.2f} "
                f"| {mem.get('temp_size_in_bytes',0)/1e9:.2f} "
                f"| {coll:.2f} | {r.get('compile_s',0):.0f} |"
            )
    return "\n".join(out)


def roofline_table() -> str:
    recs = [r for r in _load(ROOFLINE_DIR) if r.get("status") == "OK"]
    out = [
        "### Roofline (single-pod 16x16, 256 chips; v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n",
        "| arch | shape | compute ms | memory ms | collective ms | dominant | useful FLOPs ratio | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))
    for r in sorted(recs, key=key):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {_fix_hint(r)} |"
        )
    doms = {}
    for r in recs:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    out.append(f"\nDominant-term census: {doms}\n")
    return "\n".join(out)


def main():
    print(dryrun_tables())
    print()
    print(roofline_table())


if __name__ == "__main__":
    main()
