"""Real socket transport for the serving front-end: HTTP/1.1 + SSE
(ISSUE 10).

PR 9's :class:`~repro.serving.frontend.ServingFrontend` answers *who is
admitted, what do they stream, what latency did they see* — but its
callers were in-process threads. This module puts a dependency-free wire
protocol in front of it (stdlib ``http.server``, threaded), the
token-level-stream vs system-level-scheduler split AgentOS (PAPERS.md)
architects and the ROADMAP's heavy-traffic north star needs:

* ``POST /v1/generate`` — JSON body (``prompt``, ``tenant``,
  ``priority``, ``max_new_tokens``, ``sampling``) answered with an SSE
  stream. Every event is one ``data: <json>`` line: first
  ``{"rid": N}``, then ``{"text": ...}`` chunks whose concatenated
  ``text`` fields are **bitwise equal** to the in-process
  :class:`TokenStream` text (chunks are JSON-escaped, so multi-byte
  codepoints and control bytes survive the wire exactly), finally
  ``{"done": true, "status": ..., "error": ...}``.
* ``GET /v1/metrics`` — the front-end's :meth:`metrics` as JSON.
* ``POST /v1/cancel/<rid>`` — maps to :meth:`ServingFrontend.cancel`.

Robustness contract:

* a full :class:`FairQueue` (``AdmissionError``) maps to **HTTP 429**
  with a ``Retry-After`` header — explicit back-pressure on the wire;
* **slow/stalled clients** cost only themselves: each connection is
  served by its own handler thread, socket writes carry a timeout, and
  the request's stream is submitted with a bounded unread backlog
  (``max_buffered_chars``) — when either trips, the request is flagged
  for a boundary cancel and the connection closes. The pump thread never
  touches a socket, so no client can block it or disturb other lanes;
* a **client disconnect mid-stream** is detected (write failure, or a
  zero-byte read polled between chunk waits) and routed through the
  existing observable-cancel path: the request finishes with status
  "cancelled" in ``finished``/``stats`` like any in-process cancel.

The pump is one daemon thread looping :meth:`ServingFrontend.step` in
bounded chunks — deferred cancels land at each chunk's admission
boundary, and admissions keep riding the backends' boundary hooks, so
none of the engine-side invariants (one host sync per window, exact
dispatch counts, never flushing a pipelined window) change on the wire
path.

A minimal stdlib client (:class:`SSEClient`, :func:`generate_sync`,
:func:`http_json`) lives here too — tests and benchmarks drive the
loopback with it, and it doubles as protocol documentation.
"""
from __future__ import annotations

import json
import select
import socket
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.serving.frontend import AdmissionError, ServingFrontend
from repro.serving.sampler import SamplingParams

_SAMPLING_KEYS = ("temperature", "top_k", "top_p", "greedy")


def _parse_sampling(obj) -> SamplingParams | None:
    if not obj:
        return None
    bad = set(obj) - set(_SAMPLING_KEYS)
    if bad:
        raise ValueError(f"unknown sampling keys: {sorted(bad)}")
    return SamplingParams(**obj)


class TransportServer:
    """Threaded HTTP/SSE front door over a :class:`ServingFrontend`.

        fe = ServingFrontend(backend, tenants={"gold": 4.0, "free": 1.0})
        with TransportServer(fe, port=0) as srv:   # port=0 -> ephemeral
            print(srv.url)                          # http://127.0.0.1:PORT
            ...

    ``start()`` launches two daemon threads: the socket accept loop
    (``ThreadingHTTPServer`` — one handler thread per connection) and the
    pump, which drives the backend in ``pump_ticks`` chunks whenever
    requests are pending. ``write_timeout_s`` bounds every socket write;
    ``max_buffered_chars`` bounds every stream's unread backlog — a
    client stalled past either gets its request cancelled at the next
    boundary. ``sndbuf`` shrinks the kernel send buffer per connection
    (tests use it to trip back-pressure quickly).
    """

    def __init__(self, frontend: ServingFrontend, host: str = "127.0.0.1",
                 port: int = 0, *, pump_ticks: int = 32, pipeline: bool = True,
                 poll_s: float = 0.05, write_timeout_s: float = 10.0,
                 max_buffered_chars: int = 1 << 20, retry_after_s: float = 1.0,
                 sndbuf: int | None = None):
        self.fe = frontend
        self.pump_ticks = pump_ticks
        self.pipeline = pipeline
        self.poll_s = poll_s
        self.write_timeout_s = write_timeout_s
        self.max_buffered_chars = max_buffered_chars
        self.retry_after_s = retry_after_s
        self.sndbuf = sndbuf
        self.stats = {"http_requests": 0, "streams_opened": 0, "streams_ok": 0,
                      "rejected_429": 0, "disconnects": 0, "stalled_writes": 0,
                      "cancels": 0, "pump_errors": 0}
        self._stats_lock = threading.Lock()
        self._stop = threading.Event()
        self._work = threading.Event()
        self._pump: threading.Thread | None = None
        self._serve: threading.Thread | None = None

        transport = self

        class Handler(_Handler):
            server_transport = transport

        class Server(ThreadingHTTPServer):
            daemon_threads = True

            def server_bind(inner):
                if sndbuf is not None:
                    # accepted sockets inherit the listener's buffer size,
                    # so a tiny SNDBUF here makes a stalled client exert
                    # TCP back-pressure after a few KB instead of a few MB
                    inner.socket.setsockopt(
                        socket.SOL_SOCKET, socket.SO_SNDBUF, sndbuf
                    )
                super().server_bind()

        self.httpd = Server((host, port), Handler)
        self.host, self.port = self.httpd.server_address[:2]

    # ------------------------------------------------------------------
    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def _bump(self, key: str, n: int = 1) -> None:
        with self._stats_lock:
            self.stats[key] += n

    def start(self) -> "TransportServer":
        self._serve = threading.Thread(
            target=self.httpd.serve_forever, name="transport-accept", daemon=True
        )
        self._pump = threading.Thread(
            target=self._pump_loop, name="transport-pump", daemon=True
        )
        self._serve.start()
        self._pump.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._work.set()
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._pump is not None:
            self._pump.join(timeout=30)

    def __enter__(self) -> "TransportServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    def _pump_loop(self) -> None:
        """The ONLY thread that drives the backend. Bounded chunks so
        deferred cancels (disconnects, stalled writers) land at admission
        boundaries with latency capped at one chunk; it never writes to a
        socket, so no client can stall it."""
        while not self._stop.is_set():
            if self.fe.pending():
                try:
                    self.fe.step(self.pump_ticks, pipeline=self.pipeline)
                except Exception:
                    self._bump("pump_errors")
                    time.sleep(self.poll_s)
            else:
                self._work.wait(self.poll_s)
                self._work.clear()

    def kick(self) -> None:
        """Wake the pump (a request was just submitted)."""
        self._work.set()


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_transport: TransportServer = None  # bound by TransportServer

    # -- plumbing -------------------------------------------------------
    def log_message(self, *args) -> None:  # tests drive hundreds of requests
        pass

    def _json(self, code: int, obj, extra_headers: dict | None = None) -> None:
        body = json.dumps(obj, ensure_ascii=True, default=str).encode("ascii")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, v)
        self.send_header("Connection", "close")
        self.close_connection = True
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> dict:
        n = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(n) if n else b""
        if not raw:
            return {}
        return json.loads(raw.decode("utf-8"))

    # -- routes ---------------------------------------------------------
    def do_GET(self) -> None:
        t = self.server_transport
        t._bump("http_requests")
        if self.path == "/v1/metrics":
            self._json(200, t.fe.metrics())
        elif self.path == "/healthz":
            self._json(200, {"ok": True, "pending": t.fe.pending()})
        else:
            self._json(404, {"error": f"no such endpoint: {self.path}"})

    def do_POST(self) -> None:
        t = self.server_transport
        t._bump("http_requests")
        if self.path == "/v1/generate":
            self._generate(t)
        elif self.path.startswith("/v1/cancel/"):
            try:
                rid = int(self.path.rsplit("/", 1)[1])
            except ValueError:
                self._json(400, {"error": "rid must be an integer"})
                return
            ok = t.fe.cancel(rid)
            if ok:
                t._bump("cancels")
            self._json(200 if ok else 404, {"rid": rid, "cancelled": ok})
        else:
            self._json(404, {"error": f"no such endpoint: {self.path}"})

    # -- the SSE stream -------------------------------------------------
    def _generate(self, t: TransportServer) -> None:
        try:
            body = self._body()
            prompt = body["prompt"]
            sampling = _parse_sampling(body.get("sampling"))
        except (KeyError, ValueError, json.JSONDecodeError) as e:
            self._json(400, {"error": f"bad request: {e!r}"})
            return
        try:
            stream = t.fe.submit(
                prompt,
                tenant=body.get("tenant", "default"),
                priority=int(body.get("priority", 0)),
                max_new_tokens=body.get("max_new_tokens"),
                sampling=sampling,
                max_buffered_chars=t.max_buffered_chars,
            )
        except AdmissionError as e:
            # explicit wire back-pressure: the queue is full, retry later
            t._bump("rejected_429")
            self._json(429, {"error": str(e)},
                       {"Retry-After": f"{t.retry_after_s:g}"})
            return
        t.kick()
        t._bump("streams_opened")

        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream; charset=utf-8")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("X-Request-Id", str(stream.rid))
        self.send_header("Connection", "close")
        self.close_connection = True
        self.end_headers()
        if t.sndbuf is not None:
            self.connection.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF,
                                       t.sndbuf)
        self.connection.settimeout(t.write_timeout_s)

        if not self._emit({"rid": stream.rid}, t, stream):
            return
        while True:
            chunk = stream.next_chunk(timeout=t.poll_s)
            if chunk is None:
                break  # closed and fully drained
            if chunk == "":
                # idle poll: the cheap moment to notice a vanished client,
                # BEFORE more tokens are generated for it
                if self._client_gone():
                    t._bump("disconnects")
                    self._cancel(t, stream)
                    return
                continue
            if not self._emit({"text": chunk}, t, stream):
                return
        self._emit({"done": True, "status": stream.status,
                    "error": stream.error}, t, stream)
        t._bump("streams_ok")

    def _emit(self, obj, t: TransportServer, stream) -> bool:
        """Write one SSE event; on a stalled (timeout) or dead socket,
        cancel ONLY this request and close. Returns False when the
        connection is over."""
        data = b"data: " + json.dumps(obj, ensure_ascii=True).encode("ascii") \
            + b"\n\n"
        try:
            self.wfile.write(data)
            self.wfile.flush()
            return True
        except (TimeoutError, socket.timeout):
            t._bump("stalled_writes")
        except OSError:
            t._bump("disconnects")
        self._cancel(t, stream)
        return False

    def _cancel(self, t: TransportServer, stream) -> None:
        """Route a dead/stalled connection through the observable-cancel
        path (deferred: applied at the pump's next admission boundary)."""
        if t.fe.cancel(stream.rid):
            t._bump("cancels")
        self.close_connection = True

    def _client_gone(self) -> bool:
        """True when the peer closed its end: the socket polls readable
        and a peek reads zero bytes. Stray pipelined bytes are ignored
        (peeked, not consumed)."""
        try:
            r, _, _ = select.select([self.connection], [], [], 0)
            if not r:
                return False
            return self.connection.recv(1, socket.MSG_PEEK) == b""
        except OSError:
            return True


# ---------------------------------------------------------------------------
# minimal stdlib client — tests, benchmarks, and protocol documentation
# ---------------------------------------------------------------------------

class SSEClient:
    """Blocking HTTP/SSE client over one raw socket.

        c = SSEClient(host, port)
        status, headers = c.generate("prompt", tenant="gold")
        for ev in c.events():      # dicts: {"rid"}, {"text"}, {"done", ...}
            ...
        c.close()

    Raw socket on purpose: tests need to close mid-stream to simulate an
    abrupt client disconnect, and to shrink ``rcvbuf`` so a stalled reader
    exerts real TCP back-pressure.
    """

    def __init__(self, host: str, port: int, *, timeout: float = 120.0,
                 rcvbuf: int | None = None):
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        if rcvbuf is not None:
            self.sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, rcvbuf)
        self.sock.settimeout(timeout)
        self.sock.connect((host, port))
        self._fp = self.sock.makefile("rb")
        self.status: int | None = None
        self.headers: dict[str, str] = {}

    def post(self, path: str, payload: dict) -> tuple[int, dict[str, str]]:
        body = json.dumps(payload).encode("utf-8")
        head = (f"POST {path} HTTP/1.1\r\nHost: x\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n\r\n").encode("ascii")
        self.sock.sendall(head + body)
        status_line = self._fp.readline().decode("ascii", "replace")
        self.status = int(status_line.split(" ", 2)[1])
        self.headers = {}
        while True:
            line = self._fp.readline().decode("ascii", "replace").rstrip("\r\n")
            if not line:
                break
            k, _, v = line.partition(":")
            self.headers[k.strip().lower()] = v.strip()
        return self.status, self.headers

    def generate(self, prompt: str, *, tenant: str = "default",
                 priority: int = 0, max_new_tokens: int | None = None,
                 sampling: dict | None = None) -> tuple[int, dict[str, str]]:
        payload = {"prompt": prompt, "tenant": tenant, "priority": priority}
        if max_new_tokens is not None:
            payload["max_new_tokens"] = max_new_tokens
        if sampling is not None:
            payload["sampling"] = sampling
        return self.post("/v1/generate", payload)

    def events(self):
        """Yield decoded SSE events until the server closes the stream."""
        datas: list[str] = []
        while True:
            raw = self._fp.readline()
            if not raw:
                return  # EOF
            line = raw.decode("utf-8").rstrip("\r\n")
            if not line:
                if datas:
                    yield json.loads("\n".join(datas))
                    datas = []
                continue
            if line.startswith("data:"):
                datas.append(line[5:].lstrip(" "))

    def body_json(self) -> dict:
        """Read a Content-Length JSON body (non-SSE responses: 429s,
        metrics, cancels)."""
        n = int(self.headers.get("content-length") or 0)
        return json.loads(self._fp.read(n).decode("utf-8")) if n else {}

    def close(self) -> None:
        """Abrupt close — mid-stream this is the client-disconnect the
        server must detect and turn into a cancel."""
        try:
            self._fp.close()
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


def generate_sync(host: str, port: int, prompt: str, **kw) -> dict:
    """One blocking request: returns ``{"http_status", "headers", "rid",
    "text", "status", "error", "events"}`` where ``text`` is the
    concatenation of every event's ``text`` field — the bytes the parity
    tests compare against the in-process handle."""
    c = SSEClient(host, port)
    try:
        status, headers = c.generate(prompt, **kw)
        out = {"http_status": status, "headers": headers, "rid": None,
               "text": "", "status": None, "error": None, "events": []}
        if status != 200:
            out["body"] = c.body_json()
            return out
        for ev in c.events():
            out["events"].append(ev)
            if "rid" in ev:
                out["rid"] = ev["rid"]
            if "text" in ev:
                out["text"] += ev["text"]
            if ev.get("done"):
                out["status"], out["error"] = ev.get("status"), ev.get("error")
        return out
    finally:
        c.close()


def http_json(host: str, port: int, method: str, path: str,
              payload: dict | None = None) -> tuple[int, dict]:
    """Plain JSON request helper (metrics, cancel, healthz)."""
    import http.client

    conn = http.client.HTTPConnection(host, port, timeout=60)
    try:
        body = json.dumps(payload).encode("utf-8") if payload is not None else None
        conn.request(method, path, body=body,
                     headers={"Content-Type": "application/json"} if body else {})
        resp = conn.getresponse()
        data = resp.read()
        return resp.status, (json.loads(data) if data else {})
    finally:
        conn.close()
