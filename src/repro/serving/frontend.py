"""Async serving front-end: admission control, token streaming, weighted
fairness, and SLO accounting over both serving backends (ISSUE 9).

The ROADMAP's north star is heavy traffic from many users; `BatchServer`
and `CortexEngine` are engines that *could* serve, but neither owns the
questions a front-end must answer: who gets the next free lane, how does a
caller see tokens before the request finishes, and what latency did each
tenant actually experience. AgentOS (PAPERS.md) frames the split this
module implements — token-level streams delivered under a system-level
scheduler — and the multi-agent-memory survey argues the serving layer is
where multi-tenant contention must be arbitrated.

Three pieces:

* :class:`FairQueue` — per-tenant weighted-fair admission. Tenants carry
  weights; each admission charges the tenant's virtual time by the
  request's token budget over its weight, and the next admission goes to
  the backlogged tenant with the smallest virtual time — so over a busy
  period token shares converge to the weight ratio (start-time fair
  queuing). Requests carry priorities: a higher class preempts WFQ order
  entirely, and a **starvation bound** caps the damage — any request that
  has waited ``starvation_rounds`` admission decisions is admitted next,
  regardless of class or virtual time.
* :class:`TokenStream` — the per-request stream handle. The backends feed
  it at commit granularity (every step on the BatchServer path, every
  drain window on the engine path) with *incremental-decoder* output, so
  iterating the handle yields text whose concatenation is bitwise equal
  to the end-of-run ``decode(tokens)`` — multi-byte codepoints split
  across a step or window boundary included. Handles are thread-safe:
  a caller may block-iterate one stream while the pump runs elsewhere.
* :class:`ServingFrontend` — ties them to a backend. Admissions happen
  ONLY through the backend's boundary hooks (``BatchServer._admit`` /
  ``CortexEngine._boundary_ops``), which the pipelined loops invoke with
  nothing in flight — so an admission never flushes a window and the
  one-host-sync-per-window / dispatch-count invariants hold unchanged.
  Per-request SLO metrics (TTFT, time-per-output-token, queue wait) and
  per-tenant aggregates, plus p50/p99 tick latency sampled from commit
  timestamps, come out of :meth:`ServingFrontend.metrics` and are
  recorded in BENCH_throughput.json's ``serving`` section by
  benchmarks/bench_serving.py.

The wire protocol lives in :mod:`repro.serving.transport` (ISSUE 10): an
HTTP/1.1 + SSE server that maps ``POST /v1/generate`` onto :meth:`submit`
/ :class:`TokenStream`, full-queue :class:`AdmissionError` onto HTTP 429,
and client disconnects / stalled writers onto the observable-cancel path
via the deferred-cancel and stream-backlog hooks in this module.
"""
from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field

from repro.core.engine import CortexEngine
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer


class AdmissionError(RuntimeError):
    """The admission queue is full — the request was rejected, not queued.
    Back-pressure is explicit: callers retry or shed load themselves."""


class ServeStalled(RuntimeError):
    """`serve()` exhausted its tick budget (or could make no progress at
    all) with requests still pending. ``stuck`` lists their rids — e.g. a
    lane whose retirement keeps being refused because side streams still
    target it."""

    def __init__(self, message: str, stuck: list[int]):
        super().__init__(message)
        self.stuck = stuck


def percentile(samples, q: float) -> float:
    """Deterministic nearest-rank percentile (rank ``ceil(q/100 · n)``,
    1-based); 0.0 on an empty sample set. ``int(round(...))`` is NOT used:
    banker's rounding picks inconsistent ranks on even-length samples."""
    if not samples:
        return 0.0
    s = sorted(samples)
    rank = min(len(s), max(1, math.ceil(q / 100.0 * len(s))))
    return float(s[rank - 1])


class TokenStream:
    """Thread-safe per-request stream handle.

    Iterating yields decoded text chunks as the backend commits them and
    stops when the request finishes (any status). ``text`` is the
    accumulated stream so far; after completion it is bitwise equal to the
    backend's final request text, which the ISSUE 9 decoder fix makes
    bitwise equal to ``tokenizer.decode(generated_tokens)``.

    **Consumer back-pressure** (ISSUE 10): the handle tracks how far its
    consumer has read (``__iter__`` / :meth:`next_chunk` advance a shared
    cursor). When ``max_buffered_chars`` is set and the unread backlog
    exceeds it — a stalled socket writer, a consumer thread that died —
    ``on_overflow(rid)`` fires ONCE, outside the lock, from the producer
    (pump) thread. The front-end maps it to a request cancel, so a stalled
    consumer sheds exactly its own request instead of growing the backlog
    without bound or ever blocking the pump.
    """

    def __init__(self, rid: int, *, max_buffered_chars: int | None = None,
                 on_overflow=None):
        self.rid = rid
        self.max_buffered_chars = max_buffered_chars
        self.on_overflow = on_overflow
        self._chunks: list[str] = []
        self._nread = 0              # chunks consumed via iter/next_chunk
        self._unread_chars = 0       # pushed minus consumed (backlog)
        self._overflowed = False
        self._cond = threading.Condition()
        self._closed = False
        self.status: str = ""        # "", then "ok" | "cancelled" | "error"
        self.error: str | None = None

    # -- producer side (frontend taps) ---------------------------------
    def _push(self, chunk: str) -> None:
        cb = None
        with self._cond:
            self._chunks.append(chunk)
            self._unread_chars += len(chunk)
            if (self.max_buffered_chars is not None and not self._overflowed
                    and self._unread_chars > self.max_buffered_chars):
                self._overflowed = True
                cb = self.on_overflow
            self._cond.notify_all()
        if cb is not None:
            cb(self.rid)

    def _close(self, status: str, error: str | None = None) -> None:
        with self._cond:
            self.status = status or "ok"
            self.error = error
            self._closed = True
            self._cond.notify_all()

    # -- consumer side ---------------------------------------------------
    @property
    def text(self) -> str:
        with self._cond:
            return "".join(self._chunks)

    @property
    def done(self) -> bool:
        with self._cond:
            return self._closed

    @property
    def overflowed(self) -> bool:
        with self._cond:
            return self._overflowed

    def next_chunk(self, timeout: float | None = None) -> str | None:
        """Next unread chunk; ``""`` on timeout with the stream still open,
        ``None`` once it is closed and fully drained. The polling primitive
        a socket writer needs: it can interleave disconnect checks between
        bounded waits instead of blocking forever in ``__iter__``."""
        with self._cond:
            self._cond.wait_for(
                lambda: self._nread < len(self._chunks) or self._closed, timeout
            )
            if self._nread >= len(self._chunks):
                return None if self._closed else ""
            chunk = self._chunks[self._nread]
            self._nread += 1
            self._unread_chars -= len(chunk)
            return chunk

    def __iter__(self):
        """Yield chunks until the stream closes (blocking mid-stream)."""
        while True:
            chunk = self.next_chunk()
            if chunk is None:
                return
            if chunk:
                yield chunk

    def result(self, timeout: float | None = None) -> str:
        """Block until the stream closes; returns the full text."""
        with self._cond:
            if not self._cond.wait_for(lambda: self._closed, timeout):
                raise TimeoutError(f"stream {self.rid} still open")
            return "".join(self._chunks)


@dataclass
class FrontRequest:
    """Front-end view of one request: identity, stream handle, SLO clocks."""

    rid: int
    prompt: str
    tenant: str
    priority: int = 0
    max_new_tokens: int = 64
    sampling: SamplingParams | None = None
    stream: TokenStream = None
    # SLO timestamps (frontend clock; None until the event happens)
    t_submit: float = 0.0
    t_admit: float | None = None
    t_first: float | None = None
    t_done: float | None = None
    tokens_out: int = 0
    status: str = ""             # "", "queued", "running", then terminal
    submit_round: int = 0        # FairQueue round at enqueue (starvation age)
    seq: int = 0                 # global arrival order (starvation FIFO key)
    backend_id: object = None    # BatchServer rid | engine agent_id
    streamed_chars: int = 0      # engine mode: chars already pushed
    cancel_requested: bool = False

    def slo_row(self) -> dict:
        ttft = (self.t_first - self.t_submit) if self.t_first is not None else None
        tpot = None
        if self.t_done is not None and self.t_first is not None and self.tokens_out > 1:
            tpot = (self.t_done - self.t_first) / (self.tokens_out - 1)
        return {
            "rid": self.rid,
            "tenant": self.tenant,
            "priority": self.priority,
            "status": self.status,
            "tokens_out": self.tokens_out,
            "queue_wait_s": (self.t_admit - self.t_submit)
            if self.t_admit is not None else None,
            "ttft_s": ttft,
            "tpot_s": tpot,
            "e2e_s": (self.t_done - self.t_submit)
            if self.t_done is not None else None,
        }


@dataclass
class TenantState:
    name: str
    weight: float = 1.0
    vtime: float = 0.0       # served budget / weight — WFQ virtual time
    tokens_out: int = 0
    admitted: int = 0
    rejected: int = 0
    queue: list = field(default_factory=list)  # FIFO of FrontRequest


class FairQueue:
    """Weighted-fair admission with priorities and a starvation bound.

    Scheduling order at each :meth:`pop` (one admission decision):

    1. **Starvation bound** — if any queued request is aged
       ``starvation_rounds`` or more (its age at a decision counts that
       decision: a request enqueued at round R has age ``k`` at the k-th
       decision after enqueue), the longest-waiting such request is
       admitted now. This bounds worst-case queue delay for ANY request at
       ``starvation_rounds`` admission decisions, whatever its weight or
       priority: a request aged exactly ``starvation_rounds`` is promoted.
    2. **Priority** — among queue heads, only the highest priority class
       present competes (higher = sooner).
    3. **WFQ** — within that class, the tenant with the smallest virtual
       time wins; ties break by name for determinism. The winner's vtime
       advances by ``max_new_tokens / weight`` (start-time fair queuing
       with the token budget as the quantum), so over a saturated period
       admitted token budgets — and hence served tokens — converge to the
       weight ratio.

    A tenant going idle does not bank credit: on enqueue its vtime is
    floored to the current virtual floor, the standard WFQ guard against a
    returning tenant monopolizing the lanes.
    """

    def __init__(self, weights: dict[str, float] | None = None, *,
                 default_weight: float = 1.0, starvation_rounds: int = 32):
        self.tenants: dict[str, TenantState] = {}
        self.default_weight = default_weight
        self.starvation_rounds = max(1, starvation_rounds)
        self.rounds = 0              # admission decisions taken
        self.starvation_promotions = 0
        self._vfloor = 0.0
        self._seq = 0                # global arrival counter
        self._lock = threading.RLock()
        for name, w in (weights or {}).items():
            self.tenant(name, weight=w)

    def tenant(self, name: str, weight: float | None = None) -> TenantState:
        with self._lock:
            t = self.tenants.get(name)
            if t is None:
                t = self.tenants[name] = TenantState(
                    name, weight if weight is not None else self.default_weight
                )
            elif weight is not None:
                t.weight = weight
            return t

    def __len__(self) -> int:
        with self._lock:
            return sum(len(t.queue) for t in self.tenants.values())

    def push(self, req: FrontRequest) -> None:
        with self._lock:
            t = self.tenant(req.tenant)
            if not t.queue:
                t.vtime = max(t.vtime, self._vfloor)
            req.submit_round = self.rounds
            req.seq = self._seq
            self._seq += 1
            t.queue.append(req)

    def remove(self, rid: int) -> FrontRequest | None:
        with self._lock:
            for t in self.tenants.values():
                for i, r in enumerate(t.queue):
                    if r.rid == rid:
                        return t.queue.pop(i)
        return None

    def pop(self) -> FrontRequest | None:
        """One admission decision (None when nothing is queued)."""
        with self._lock:
            backlogged = [t for t in self.tenants.values() if t.queue]
            if not backlogged:
                return None
            self.rounds += 1
            # the normal order: highest priority class present wins outright,
            # then weighted-fair within it — smallest virtual time, ties by
            # name for determinism
            top = max(t.queue[0].priority for t in backlogged)
            cands = [t for t in backlogged if t.queue[0].priority == top]
            normal = min(cands, key=lambda t: (t.vtime, t.name))
            # starvation bound: if any head has reached the bound, the
            # oldest such request (global arrival order) is admitted instead —
            # a promotion only counts when it actually overrides normal order.
            # `rounds` was just incremented, so `rounds - submit_round` is the
            # head's age AT this decision; `>=` admits a request aged exactly
            # `starvation_rounds` (ISSUE 10 bugfix: the old `>` promoted one
            # decision late, violating the documented bound)
            aged = [
                t for t in backlogged
                if self.rounds - t.queue[0].submit_round >= self.starvation_rounds
            ]
            if aged:
                t = min(aged, key=lambda t: t.queue[0].seq)
                if t is not normal:
                    self.starvation_promotions += 1
                return self._take(t)
            return self._take(normal)

    def _take(self, t: TenantState) -> FrontRequest:
        req = t.queue.pop(0)
        t.vtime += req.max_new_tokens / max(t.weight, 1e-9)
        self._vfloor = max(
            self._vfloor,
            min((x.vtime for x in self.tenants.values() if x.queue), default=t.vtime),
        )
        t.admitted += 1
        return req

    def charge(self, tenant: str, tokens: int) -> None:
        with self._lock:
            self.tenant(tenant).tokens_out += tokens


class ServingFrontend:
    """Admission + streaming + fairness + SLOs over a serving backend.

    ``backend`` is a :class:`~repro.serving.server.BatchServer` or a
    :class:`~repro.core.engine.CortexEngine`; the front-end installs its
    admission hook and stream taps and never touches device state itself.

    BatchServer mode: a request is one server request (EOS or
    ``max_new_tokens`` completes it); streams advance every commit.
    Engine mode: a request is a main agent (``submit``-ed into a free
    river lane at a window boundary, ``retire_main``-ed when its budget is
    met); streams advance every drain, so token counts are window-granular
    — a request completes at the first boundary where its budget is met,
    overshooting it by at most the pipelined windows in flight (the engine
    is never flushed mid-window to enforce an exact count).

    ``max_queue`` bounds the admission backlog; a submit past it raises
    :class:`AdmissionError` (explicit back-pressure, counted per tenant).
    """

    def __init__(self, backend, *, tenants: dict[str, float] | None = None,
                 default_weight: float = 1.0, max_queue: int = 256,
                 starvation_rounds: int = 32, default_max_new_tokens: int = 64,
                 clock=time.monotonic):
        self.backend = backend
        self.clock = clock
        self.max_queue = max_queue
        self.default_max_new_tokens = default_max_new_tokens
        self.fq = FairQueue(tenants, default_weight=default_weight,
                            starvation_rounds=starvation_rounds)
        self.requests: dict[int, FrontRequest] = {}
        self.live: dict[object, FrontRequest] = {}  # backend_id -> request
        self._rid = 0
        self._lock = threading.RLock()
        # the thread that owns the backend (set by serve()/step() and the
        # transport pump). Backend state is NOT thread-safe: a cancel from
        # any other thread is deferred — flagged on the request and applied
        # at the next admission boundary inside the pump's own loop.
        self._pump_thread: threading.Thread | None = None
        # tick-latency sampling: (clock, backend step counter) at the last
        # commit observation; each later commit contributes
        # (dt / dsteps) samples — amortized per-tick latency as a caller
        # actually experiences it, pipelining and drain batching included
        self._tick_samples: list[float] = []
        self._last_mark: tuple[float, int] | None = None

        if isinstance(backend, BatchServer):
            self._mode = "batch"
            backend.admission_hook = self._admit_batch
        elif isinstance(backend, CortexEngine):
            self._mode = "engine"
            backend.admission_hook = self._admit_engine
            backend.stream_tap = self._engine_tap
        else:
            raise TypeError(f"unsupported backend: {type(backend).__name__}")

    # ------------------------------------------------------------------
    def submit(self, prompt: str, *, tenant: str = "default", priority: int = 0,
               max_new_tokens: int | None = None,
               sampling: SamplingParams | None = None,
               max_buffered_chars: int | None = None) -> TokenStream:
        """Queue a request; returns its stream handle immediately. Raises
        :class:`AdmissionError` when the backlog is at ``max_queue``.

        ``max_buffered_chars`` bounds the stream's unread backlog (ISSUE
        10): a consumer that stalls past it — a socket writer stuck on a
        dead client — gets its request cancelled at the next boundary
        instead of buffering without bound. ``None`` (default) keeps the
        in-process unbounded behavior."""
        with self._lock:
            if len(self.fq) >= self.max_queue:
                self.fq.tenant(tenant).rejected += 1
                raise AdmissionError(
                    f"admission queue full ({self.max_queue}); tenant {tenant!r}"
                )
            self._rid += 1
            req = FrontRequest(
                self._rid, prompt, tenant, priority,
                max_new_tokens or self.default_max_new_tokens, sampling,
                TokenStream(self._rid, max_buffered_chars=max_buffered_chars,
                            on_overflow=self._overflow),
                t_submit=self.clock(), status="queued",
            )
            self.requests[req.rid] = req
            self.fq.push(req)
            return req.stream

    def _overflow(self, rid: int) -> None:
        """A stream's unread backlog crossed its bound (fired from the pump
        thread mid-commit): flag the request for a boundary cancel — never
        re-enter the backend from inside its own tap."""
        with self._lock:
            req = self.requests.get(rid)
            if req is not None and req.status not in ("ok", "cancelled", "error"):
                req.cancel_requested = True

    def _foreign_pump(self) -> bool:
        t = self._pump_thread
        return (t is not None and t.is_alive()
                and t is not threading.current_thread())

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or running request; its stream closes with
        status "cancelled" (queued immediately, running at the next
        boundary in engine mode / via BatchServer.cancel in batch mode).
        Called from a thread that does not own the backend — a transport
        handler racing the pump — the running-request cancel is deferred to
        the next admission boundary in BOTH modes."""
        with self._lock:
            req = self.requests.get(rid)
            if req is None or req.status in ("ok", "cancelled", "error"):
                return False
            if self.fq.remove(rid) is not None:
                self._finish(req, "cancelled")
                return True
            if self._mode == "batch" and not self._foreign_pump():
                return self.backend.cancel(req.backend_id)  # tap closes stream
            req.cancel_requested = True  # honored at the next boundary
            return True

    def pending(self) -> int:
        with self._lock:
            return len(self.fq) + len(self.live)

    # ------------------------------------------------------------------
    def step(self, ticks: int | None = None, *, pipeline: bool = True) -> int:
        """Drive the backend for ONE bounded chunk; returns the backend
        ticks it actually advanced. The transport pump loops this forever
        (deferred cancels land at each chunk's admission boundary);
        :meth:`serve` loops it until idle under a total budget."""
        self._pump_thread = threading.current_thread()
        if self._mode == "batch":
            before = self.backend.stats["steps"]
            self.backend.run_until_done(
                max_ticks=ticks if ticks is not None else 256, pipeline=pipeline
            )
            return max(0, self.backend.stats["steps"] - before)
        eng = self.backend
        before = eng.stats["ticks"]
        eng.run(ticks if ticks is not None else 8 * eng.sync_every)
        return max(0, eng.stats["ticks"] - before)

    def serve(self, *, max_ticks: int = 100_000, pipeline: bool = True) -> None:
        """Pump the backend until every queued/live request completes.
        Admissions, retirements, and stream delivery all happen inside the
        backend's own loop via the installed hooks — this method just
        drives it and returns when the front-end is idle.

        ``max_ticks`` is a TOTAL tick budget across the whole call (ISSUE
        10 bugfix: it used to cap single iterations of an unbounded loop,
        spinning forever when a request could never retire — e.g. a lane
        whose ``retire_main`` keeps refusing while side streams target it).
        Exhausting it — or a chunk that provably cannot advance — raises
        :class:`ServeStalled` with the stuck rids."""
        spent = 0
        while self.pending():
            chunk = max_ticks - spent
            if chunk <= 0:
                self._raise_stalled(f"serve() exhausted max_ticks={max_ticks}")
            if self._mode == "engine":
                chunk = min(chunk, 8 * self.backend.sync_every)
            advanced = self.step(chunk, pipeline=pipeline)
            spent += advanced
            if advanced == 0 and self.pending():
                self._raise_stalled(
                    "serve() made no progress (backend refuses to run)"
                )

    def _raise_stalled(self, why: str):
        with self._lock:
            stuck = sorted(
                {r.rid for r in self.live.values()}
                | {r.rid for t in self.fq.tenants.values() for r in t.queue}
            )
        raise ServeStalled(f"{why}; stuck rids: {stuck}", stuck)

    # ------------------------------------------------------------------
    def _finish(self, req: FrontRequest, status: str, error: str | None = None):
        req.status = status
        req.t_done = self.clock()
        req.stream._close(status, error)
        self.live.pop(req.backend_id, None)

    def _note_progress(self, now: float, steps: int) -> None:
        if self._last_mark is not None:
            t0, s0 = self._last_mark
            if steps > s0 and now > t0:
                self._tick_samples.append((now - t0) / (steps - s0))
        self._last_mark = (now, steps)

    # -- BatchServer backend -------------------------------------------
    def _admit_batch(self) -> int:
        """Admission-boundary hook: fill free lanes from the fair queue.
        Runs inside ``BatchServer._admit`` — always at a step boundary with
        nothing in flight, so admission never costs a flush. Deferred
        cancels (transport disconnects, stream-backlog overflow — flagged
        from threads that do not own the backend) are applied here first,
        so the lanes they free are refilled in the same boundary."""
        srv = self.backend
        admitted = 0
        for req in list(self.live.values()):
            if req.cancel_requested:
                srv.cancel(req.backend_id)  # tap -> _finish: observable
        while True:
            free = sum(r is None for r in srv.lanes) - len(srv.queue) - len(srv._resume)
            if free <= 0:
                break
            with self._lock:
                req = self.fq.pop()
                if req is None:
                    break
                rid = srv.submit(req.prompt, req.max_new_tokens, req.sampling)
                req.backend_id = rid
                req.t_admit = self.clock()
                req.status = "running"
                self.live[rid] = req
                srv.taps[rid] = self._batch_tap(req)
            admitted += 1
        return admitted

    def _batch_tap(self, req: FrontRequest):
        def tap(sreq, chunk: str, toks, done: bool):
            now = self.clock()
            self._note_progress(now, self.backend.stats["steps"])
            if toks:
                if req.t_first is None:
                    req.t_first = now
                req.tokens_out += len(toks)
                self.fq.charge(req.tenant, len(toks))
            if chunk:
                req.stream._push(chunk)
            if done:
                self._finish(req, sreq.status or "ok", sreq.error)
        return tap

    # -- CortexEngine backend ------------------------------------------
    def _admit_engine(self) -> int:
        """Window-boundary hook (runs in ``CortexEngine._boundary_ops``):
        retire request lanes whose budget is met (or cancelled), then admit
        queued requests into the freed river lanes. Both are boundary ops —
        the pipelined window is never flushed by an admission."""
        eng = self.backend
        did = 0
        for req in list(self.live.values()):
            if req.cancel_requested or req.tokens_out >= req.max_new_tokens:
                try:
                    self._retire_engine_req(req)
                except ValueError:
                    continue  # side streams still target the lane; next boundary
                did += 1
        while True:
            lane = eng._free_main_lane()
            if lane < 0:
                break
            with self._lock:
                req = self.fq.pop()
                if req is None:
                    break
                aid = f"fe{req.rid}"
                req.backend_id = aid
                req.t_admit = self.clock()
                req.status = "running"
                self.live[aid] = req
                eng.submit(req.prompt, lane=lane, sampling=req.sampling,
                           agent_id=aid)
            did += 1
        return did

    def _retire_engine_req(self, req: FrontRequest) -> None:
        eng = self.backend
        rec = eng.registry.get(req.backend_id)
        view = eng.mains[rec.lane]
        eng.retire_main(rec.lane)  # flushes the decoder into view.text
        # deliver the flush tail (text beyond what the taps streamed):
        # stream text ends bitwise equal to the final decode
        prompt_chars = len(req.prompt)
        tail = view.text[prompt_chars + req.streamed_chars:]
        if tail:
            req.stream._push(tail)
        self._finish(req, "cancelled" if req.cancel_requested else "ok")

    def _engine_tap(self, view, chunk: str, toks) -> None:
        req = self.live.get(view.agent_id)
        if req is None or view.kind != "main":
            return  # side streams and non-frontend agents pass through
        now = self.clock()
        self._note_progress(now, self.backend.stats["ticks"])
        if toks:
            # guard like _batch_tap (ISSUE 10 bugfix): a drain callback with
            # no tokens for this lane must not stamp TTFT — t_first means "a
            # generated token exists", not "a drain happened"
            if req.t_first is None:
                req.t_first = now
            req.tokens_out += len(toks)
            self.fq.charge(req.tenant, len(toks))
        if chunk:
            req.stream._push(chunk)
            req.streamed_chars += len(chunk)

    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        """Per-request SLO rows, per-tenant aggregates (token shares,
        TTFT percentiles, fairness counters), and tick-latency percentiles
        — the ``serving`` section bench_serving.py records."""
        with self._lock:
            rows = [r.slo_row() for r in self.requests.values()]
            total_tokens = sum(t.tokens_out for t in self.fq.tenants.values())
            tenants = {}
            for name, t in self.fq.tenants.items():
                ttfts = [r["ttft_s"] for r in rows
                         if r["tenant"] == name and r["ttft_s"] is not None]
                tenants[name] = {
                    "weight": t.weight,
                    "tokens_out": t.tokens_out,
                    "token_share": t.tokens_out / total_tokens if total_tokens else 0.0,
                    "admitted": t.admitted,
                    "rejected": t.rejected,
                    "queued": len(t.queue),
                    "ttft_p50_s": percentile(ttfts, 50),
                    "ttft_p99_s": percentile(ttfts, 99),
                }
            ttfts = [r["ttft_s"] for r in rows if r["ttft_s"] is not None]
            done = [r for r in rows if r["status"] in ("ok", "cancelled", "error")]
            return {
                "requests": rows,
                "tenants": tenants,
                "fairness": {
                    "admission_rounds": self.fq.rounds,
                    "starvation_promotions": self.fq.starvation_promotions,
                    "starvation_rounds": self.fq.starvation_rounds,
                },
                "ttft_s": {"p50": percentile(ttfts, 50),
                           "p99": percentile(ttfts, 99)},
                "tick_latency_s": {
                    "p50": percentile(self._tick_samples, 50),
                    "p99": percentile(self._tick_samples, 99),
                    "n": len(self._tick_samples),
                },
                "completed": len(done),
                "backend": self._mode,
            }
