"""Continuous-batching single-model server (no multi-agent logic).

The plain-serving baseline the paper compares against: N requests = N full
KV caches. Lanes are recycled as requests finish; prefill is per-admission,
decode is one fused batched step per tick. The CortexEngine (core/engine.py)
is the Warp-Cortex counterpart with shared weights + synapse sides.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.sampler import SamplingParams, sample_lanes, stack_lane_params, static_flags


@dataclass
class Request:
    rid: int
    prompt: str
    max_new_tokens: int = 64
    sampling: SamplingParams | None = None  # None -> server default
    tokens: list = field(default_factory=list)
    text: str = ""
    done: bool = False
    lane: int = -1


class BatchServer:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        tokenizer: ByteTokenizer,
        *,
        n_lanes: int = 8,
        capacity: int = 1024,
        sampling: SamplingParams = SamplingParams(temperature=0.8),
        cache_kind: str = "full",
        seed: int = 0,
    ):
        self.params, self.cfg, self.tok = params, cfg, tokenizer
        self.sampling = sampling
        self.spec = model_lib.CacheSpec(kind=cache_kind, capacity=capacity)
        self.caches = model_lib.init_caches(cfg, n_lanes, self.spec)
        self.n_lanes = n_lanes
        self.lanes: list[Request | None] = [None] * n_lanes
        self.positions = np.zeros(n_lanes, np.int64)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._key = jax.random.key(seed)
        self._rid = 0
        # per-lane sampling arrays + static flags, rebuilt only when lane
        # composition changes (admission / completion), not per token
        self._samp_cache = None

        self._jit_prefill = jax.jit(
            lambda p, toks, c: model_lib.prefill(p, cfg, {"tokens": toks}, c, spec=self.spec)
        )
        self._jit_decode = jax.jit(
            lambda p, toks, pos, c: model_lib.decode_step(
                p, cfg, {"tokens": toks, "positions": pos}, c, spec=self.spec
            )
        )

    def submit(self, prompt: str, max_new_tokens: int = 64,
               sampling: SamplingParams | None = None) -> int:
        """``sampling`` overrides the server default for THIS request only —
        per-lane params ride one shared sampling pass (sample_lanes), so a
        greedy request batches with exploratory ones."""
        self._rid += 1
        self.queue.append(Request(self._rid, prompt, max_new_tokens, sampling))
        return self._rid

    def _admit(self):
        for lane in range(self.n_lanes):
            if self.lanes[lane] is None and self.queue:
                req = self.queue.pop(0)
                ids = self.tok.encode(req.prompt, bos=True)
                lane_cache = jax.tree.map(lambda a: a[:, lane : lane + 1], self.caches)
                # reset the lane
                lane_cache = jax.tree.map(lambda a: jnp.zeros_like(a), lane_cache)
                _, _, lane_cache = self._jit_prefill(
                    self.params, jnp.asarray([ids], jnp.int32), lane_cache
                )
                self.caches = jax.tree.map(
                    lambda full, part: full.at[:, lane : lane + 1].set(part), self.caches, lane_cache
                )
                req.tokens = list(ids)
                req.lane = lane
                self.positions[lane] = len(ids)
                self.lanes[lane] = req
                self._samp_cache = None

    def tick(self):
        self._admit()
        if not any(self.lanes):
            return
        toks = jnp.asarray(
            [r.tokens[-1] if r else 0 for r in self.lanes], jnp.int32
        )
        pos = jnp.asarray(self.positions, jnp.int32)
        self._key, k = jax.random.split(self._key)
        logits, _, self.caches = self._jit_decode(self.params, toks, pos, self.caches)
        if self._samp_cache is None:
            # empty lanes get the server default — their draws are discarded,
            # so they must not force the greedy-argmax path on everyone else
            lane_sp = [(r.sampling or self.sampling) if r else self.sampling
                       for r in self.lanes]
            self._samp_cache = (stack_lane_params(lane_sp), *static_flags(lane_sp))
        lanes_samp, use_filters, any_greedy = self._samp_cache
        new = np.asarray(sample_lanes(
            k, logits, lanes_samp, use_filters=use_filters, any_greedy=any_greedy,
        ))
        for lane, req in enumerate(self.lanes):
            if req is None:
                continue
            t = int(new[lane])
            req.tokens.append(t)
            req.text += self.tok.decode([t])
            self.positions[lane] += 1
            gen = len(req.tokens) - len(self.tok.encode(req.prompt, bos=True))
            if t == self.tok.eos_id or gen >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.lanes[lane] = None
                self._samp_cache = None

    def run_until_done(self, max_ticks: int = 4096):
        for _ in range(max_ticks):
            if not self.queue and not any(self.lanes):
                break
            self.tick()
        return self.finished
