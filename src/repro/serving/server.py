"""Continuous-batching single-model server (no multi-agent logic).

The plain-serving baseline the paper compares against: N requests = N full
KV caches. Lanes are recycled as requests finish; prefill is per-admission,
decode is one fused batched step per tick. The CortexEngine (core/engine.py)
is the Warp-Cortex counterpart with shared weights + synapse sides.

Pipelined drain (default in :meth:`run_until_done`): sampled tokens stay on
the device and feed the next decode step directly, so step *t+1* is
dispatched BEFORE step *t*'s tokens are pulled to the host — detokenization,
EOS checks, and admission bookkeeping overlap the device's next step. The
speculation is exact: nothing is donated, so when the fetched tokens reveal
a lane completion the in-flight step is discarded (key/caches/positions roll
back) and re-run from the corrected lane composition — token streams are
bitwise identical to the serial ``tick()`` loop. Completions driven by
``max_new_tokens`` are host-predictable, so the server only speculates when
no lane is at its budget; only surprise EOS tokens cost a rollback.

Per-lane sampling arrays ride a :class:`repro.serving.sampler.SampCache`,
invalidated on EVERY lane-composition change (admission, completion, and
mid-flight :meth:`cancel`): a stale cache would hand a recycled lane the
previous request's sampling params.

Parking (ISSUE 7): an idle request can be :meth:`park`-ed — its lane's KV
slice moves into a :class:`repro.memory.SynapseStore` (warm host RAM, cold
zstd disk under pressure) and the lane frees for other traffic.
:meth:`unpark` prefetches the slice back on a background thread; the
request re-enters at the next admission boundary with its exact cache
bytes and position, so its greedy continuation is unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.memory import SynapseStore
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.sampler import SampCache, SamplingParams, sample_lanes


@dataclass
class Request:
    rid: int
    prompt: str
    max_new_tokens: int = 64
    sampling: SamplingParams | None = None  # None -> server default
    tokens: list = field(default_factory=list)
    text: str = ""
    done: bool = False
    lane: int = -1
    prompt_len: int = 0  # len(encode(prompt, bos=True)), set at admission
    error: str | None = None  # terminal failure (lost parked snapshot, ...)
    # how the request left the server: "" while live, then "ok" (EOS/budget),
    # "cancelled" (ISSUE 9: a cancel is an observable completion — the rid
    # lands in `finished` like any other outcome), or "error"
    status: str = ""
    # stateful UTF-8 decoder (ISSUE 9 bugfix): tokens decode incrementally,
    # so a codepoint split across steps never becomes U+FFFD in `text`
    decoder: object = field(default=None, repr=False)


class BatchServer:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        tokenizer: ByteTokenizer,
        *,
        n_lanes: int = 8,
        capacity: int = 1024,
        sampling: SamplingParams = SamplingParams(temperature=0.8),
        cache_kind: str = "full",
        seed: int = 0,
        mesh=None,
        store: SynapseStore | None = None,
        wake_deadline_s: float | None = None,
    ):
        """``mesh``: a lane mesh (``launch.mesh.make_lane_mesh``) spreads
        the per-request KV lanes over its ``lane`` axis — the plain-serving
        counterpart of the engine's lane-sharded TickState. Weights
        replicate; the batched decode partitions over lanes via GSPMD."""
        self.params, self.cfg, self.tok = params, cfg, tokenizer
        self.sampling = sampling
        self.spec = model_lib.CacheSpec(kind=cache_kind, capacity=capacity)
        self.caches = model_lib.init_caches(cfg, n_lanes, self.spec)
        self.n_lanes = n_lanes
        self.mesh = mesh
        cache_sh = None
        if mesh is not None and "lane" in getattr(mesh, "axis_names", ()):
            from repro.launch import sharding as shard_rules

            cache_sh = shard_rules.shardings_for(
                shard_rules.lane_cache_specs(self.caches, mesh), mesh
            )
            rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
            self.caches = jax.device_put(self.caches, cache_sh)
            self.params = jax.device_put(self.params, rep)
        self._rep = (
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
            if cache_sh is not None
            else None
        )
        self.lanes: list[Request | None] = [None] * n_lanes
        self.positions = np.zeros(n_lanes, np.int64)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        # parked requests: lane-less, KV slice in the store's warm/cold tiers
        self.store = store if store is not None else SynapseStore()
        self.parked: dict[int, Request] = {}
        self._resume: list[tuple[Request, object]] = []  # (request, WakeTicket)
        self._key = jax.random.key(seed)
        self._rid = 0
        # per-lane sampling arrays + static flags, rebuilt only when lane
        # composition changes — every admission/completion/cancel must
        # invalidate (see SampCache)
        self._samp_cache = SampCache()
        self.stats = {"steps": 0, "overlapped": 0, "rollbacks": 0,
                      "lost_requests": 0, "cancelled": 0}
        # default promotion deadline applied to unpark() unless overridden
        # per call (mirrors the engine's wake_deadline_s)
        self.wake_deadline_s = wake_deadline_s
        # serving front-end hooks (ISSUE 9). ``taps[rid]`` is called as
        # tap(req, chunk, toks, done) at commit granularity — the moment a
        # step's tokens land on the host — so callers stream text mid-
        # flight; chunks are incremental-decoder output, so their
        # concatenation equals the final text bitwise. ``admission_hook``
        # runs at the top of every admission boundary (and ONLY there: the
        # pipelined loop reaches _admit with nothing in flight), letting a
        # front-end feed `queue` without ever flushing a window.
        self.taps: dict[int, object] = {}
        self.admission_hook = None

        self._jit_prefill = jax.jit(
            lambda p, toks, c: model_lib.prefill(p, cfg, {"tokens": toks}, c, spec=self.spec)
        )
        # pin the decode's cache output to the lane placement: GSPMD would
        # otherwise be free to reshard the caches every step
        decode_kw = {}
        if cache_sh is not None:
            decode_kw["out_shardings"] = (rep, rep, cache_sh)
        self._jit_decode = jax.jit(
            lambda p, toks, pos, c: model_lib.decode_step(
                p, cfg, {"tokens": toks, "positions": pos}, c, spec=self.spec
            ),
            **decode_kw,
        )

    def submit(self, prompt: str, max_new_tokens: int = 64,
               sampling: SamplingParams | None = None) -> int:
        """``sampling`` overrides the server default for THIS request only —
        per-lane params ride one shared sampling pass (sample_lanes), so a
        greedy request batches with exploratory ones."""
        self._rid += 1
        req = Request(self._rid, prompt, max_new_tokens, sampling)
        req.decoder = self.tok.stream_decoder()
        self.queue.append(req)
        return self._rid

    def _finish(self, req: Request, status: str, error: str | None = None):
        """Every terminal path funnels here: the request is marked done with
        its outcome, its decoder flushes (final text == one-shot decode
        bitwise), it lands in `finished`, and its tap fires once more with
        done=True so a streaming caller always observes the end."""
        if error is not None:
            req.error = error
        req.status = status
        req.done = True
        tail = req.decoder.flush() if req.decoder is not None else ""
        req.text += tail
        self.finished.append(req)
        tap = self.taps.pop(req.rid, None)
        if tap is not None:
            tap(req, tail, [], True)

    def cancel(self, rid: int) -> bool:
        """Retire a request mid-flight (queued, decoding, parked, or
        resuming). Freeing a lane is a composition change: the samp cache
        must be invalidated so the next admission rebuilds the stacked
        params — a recycled lane must never inherit the cancelled request's
        sampling. A cancelled rid does NOT vanish (ISSUE 9 bugfix): it is
        marked done with status "cancelled", appended to `finished`, and
        counted in ``stats["cancelled"]`` — every observable surface agrees
        on what happened to it."""
        req, lane = None, -1
        for i, r in enumerate(self.queue):
            if r.rid == rid:
                req = self.queue.pop(i)
                break
        if req is None:
            for l, r in enumerate(self.lanes):
                if r is not None and r.rid == rid:
                    req, lane = r, l
                    self.lanes[l] = None
                    self._samp_cache.invalidate()
                    break
        if req is None and rid in self.parked:
            req = self.parked.pop(rid)
            self.store.drop(f"req{rid}")
        if req is None:
            for i, (r, _) in enumerate(self._resume):
                if r.rid == rid:
                    req = r
                    self._resume.pop(i)
                    self.store.drop(f"req{rid}")
                    break
        if req is None:
            return False
        self.stats["cancelled"] += 1
        self._finish(req, "cancelled")
        return True

    # ------------------------------------------------------------------
    def park(self, rid: int) -> bool:
        """Demote a decoding request off its lane: the lane's KV slice and
        position move to the store (host RAM, spilling to disk by the
        store's LRU policy) and the lane frees. Restoration is bitwise, so
        the request's greedy stream continues exactly where it stopped."""
        for lane, req in enumerate(self.lanes):
            if req is not None and req.rid == rid:
                snap = {
                    "caches": jax.tree.map(
                        lambda a: a[:, lane : lane + 1], self.caches
                    ),
                    "position": np.int64(self.positions[lane]),
                }
                self.store.put(
                    f"req{rid}", snap, meta={"kind": "request", "rid": rid}
                )  # host pull inside
                self.lanes[lane] = None
                req.lane = -1
                self._samp_cache.invalidate()
                self.parked[rid] = req
                return True
        return False

    def unpark(self, rid: int, *, deadline_s: float | None = None) -> bool:
        """Start the async promotion of a parked request; it re-enters at
        the next admission boundary (before queued prompts — it already
        paid its prefill). ``deadline_s`` bounds THIS request's promotion:
        if the prefetch has not landed by then, the request fails with a
        recorded error instead of stalling the admission loop (per-request
        degradation — other streams are untouched)."""
        req = self.parked.pop(rid, None)
        if req is None:
            return False
        rep = self._rep

        def put_fn(host, _s=rep):
            return jax.device_put(host, _s) if _s is not None else jax.device_put(host)

        if deadline_s is None:
            deadline_s = self.wake_deadline_s  # server-wide default (ISSUE 9)
        self._resume.append(
            (req, self.store.prefetch(f"req{rid}", put_fn, deadline_s=deadline_s))
        )
        return True

    def _fail_resume(self, req: Request, err: BaseException | None) -> None:
        """Terminal per-request degradation: the parked snapshot could not
        be promoted (quarantined blob, deadline, dead worker). The request
        finishes with ``error`` set; every other stream keeps decoding."""
        self.store.drop(f"req{req.rid}")
        self.stats["lost_requests"] += 1
        self._finish(req, "error", repr(err) if err is not None else "wake failed")

    def _admit_unparked(self, *, wait: bool = False):
        """Land resume tickets whose prefetched buffers are ready (all of
        them with ``wait=True``) into free lanes. Failed tickets — loss,
        deadline expiry, a dead prefetch worker (healed here) — retire
        their request with ``error`` set instead of raising mid-admission."""
        if self._resume:
            self.store.heal_worker()
        still = []
        for req, ticket in self._resume:
            ticket.expire()
            if not ticket.failed():
                lane = next((i for i, r in enumerate(self.lanes) if r is None), -1)
                if lane < 0 or not (wait or ticket.ready()):
                    still.append((req, ticket))
                    continue
                if not ticket.ready():
                    try:
                        ticket.result(timeout=ticket.remaining())
                    except Exception:
                        pass  # terminal state recorded on the ticket
                    ticket.expire()
            if ticket.failed():
                self._fail_resume(req, ticket.error)
                continue
            part = ticket.result()
            self.caches = jax.tree.map(
                lambda full, piece: full.at[:, lane : lane + 1].set(
                    piece.astype(full.dtype)
                ),
                self.caches,
                part["caches"],
            )
            self.positions[lane] = int(part["position"])
            req.lane = lane
            self.lanes[lane] = req
            self._samp_cache.invalidate()
            self.store.drop(f"req{req.rid}")
        self._resume = still

    def _admit(self):
        if self.admission_hook is not None:
            # front-end admission control runs at this boundary only — the
            # hook may push into `queue` but never touches device state
            self.admission_hook()
        self._admit_unparked()
        for lane in range(self.n_lanes):
            if self.lanes[lane] is None and self.queue:
                req = self.queue.pop(0)
                ids = self.tok.encode(req.prompt, bos=True)
                lane_cache = jax.tree.map(lambda a: a[:, lane : lane + 1], self.caches)
                # reset the lane
                lane_cache = jax.tree.map(lambda a: jnp.zeros_like(a), lane_cache)
                _, _, lane_cache = self._jit_prefill(
                    self.params, jnp.asarray([ids], jnp.int32), lane_cache
                )
                self.caches = jax.tree.map(
                    lambda full, part: full.at[:, lane : lane + 1].set(part), self.caches, lane_cache
                )
                req.tokens = list(ids)
                req.lane = lane
                req.prompt_len = len(ids)
                self.positions[lane] = len(ids)
                self.lanes[lane] = req
                self._samp_cache.invalidate()

    # ------------------------------------------------------------------
    def _lane_params(self):
        # empty lanes get the server default — their draws are discarded,
        # so they must not force the greedy-argmax path on everyone else
        return [(r.sampling or self.sampling) if r else self.sampling
                for r in self.lanes]

    def _step(self, toks):
        """ONE batched decode + shared sampling dispatch. ``toks`` may be a
        host list or the previous step's on-device sampled tokens (the
        pipelined path — no host round-trip). Returns the sampled tokens as
        a DEVICE array and advances the occupied lanes' positions."""
        pos = jnp.asarray(self.positions, jnp.int32)
        self._key, k = jax.random.split(self._key)
        logits, _, self.caches = self._jit_decode(self.params, toks, pos, self.caches)
        lanes_samp, use_filters, any_greedy = self._samp_cache.get(self._lane_params)
        sampled = sample_lanes(
            k, logits, lanes_samp, use_filters=use_filters, any_greedy=any_greedy,
        )
        for lane, req in enumerate(self.lanes):
            if req is not None:
                self.positions[lane] += 1
        self.stats["steps"] += 1
        return sampled

    def _host_toks(self):
        return jnp.asarray(
            [r.tokens[-1] if r else 0 for r in self.lanes], jnp.int32
        )

    def _commit(self, new_np) -> bool:
        """Apply one step's sampled tokens to the request views; returns
        True when the lane composition changed (a request finished).

        Text accrues through the request's stateful UTF-8 decoder (ISSUE 9
        bugfix): the old per-token ``decode([t])`` turned every multi-byte
        codepoint into replacement chars, since no single byte of it is
        valid alone. The decoder buffers the incomplete tail instead, and
        the terminal flush in :meth:`_finish` makes the final ``req.text``
        bitwise equal to ``decode(req.tokens[prompt_len:])``."""
        changed = False
        for lane, req in enumerate(self.lanes):
            if req is None:
                continue
            t = int(new_np[lane])
            req.tokens.append(t)
            chunk = req.decoder.feed([t]) if req.decoder is not None \
                else self.tok.decode([t])
            req.text += chunk
            gen = len(req.tokens) - req.prompt_len
            tap = self.taps.get(req.rid)
            if tap is not None:
                tap(req, chunk, [t], False)
            if t == self.tok.eos_id or gen >= req.max_new_tokens:
                self.lanes[lane] = None
                self._samp_cache.invalidate()
                changed = True
                self._finish(req, "ok")
        return changed

    def _can_speculate(self) -> bool:
        """The next step may be dispatched before this step's tokens reach
        the host only if the composition provably cannot change: no queued
        request waiting on a free lane, and no lane at its token budget.
        EOS completions stay unpredictable — those cost a rollback instead.
        """
        if (self.queue or self._resume) and any(r is None for r in self.lanes):
            return False
        for req in self.lanes:
            if req is not None:
                # generated count AFTER the in-flight step commits
                if len(req.tokens) + 1 - req.prompt_len >= req.max_new_tokens:
                    return False
        return True

    def tick(self):
        """One serial step: decode, sample, pull tokens, commit."""
        self._admit()
        if not any(self.lanes):
            return
        self._commit(np.asarray(self._step(self._host_toks())))

    def run_until_done(self, max_ticks: int = 4096, *, pipeline: bool = True):
        """Drive admissions + decode until queue and lanes empty.

        ``pipeline=True`` (default) keeps the sampled tokens on the device
        and dispatches step *t+1* before step *t*'s host drain; a surprise
        EOS rolls the un-donated speculative step back and re-runs it from
        the corrected composition, so the streams match the serial loop
        bitwise. ``pipeline=False`` is the serial reference."""
        if not pipeline:
            for _ in range(max_ticks):
                if not self.queue and not any(self.lanes):
                    if not self._resume:
                        break
                    self._admit_unparked(wait=True)  # idle: block on tickets
                self.tick()
            return self.finished

        occupied = lambda: jnp.asarray([r is not None for r in self.lanes])
        inflight = None  # device tokens of the dispatched-but-undrained step
        ticks = 0
        while ticks < max_ticks:
            if inflight is None:
                self._admit()
                if not any(self.lanes):
                    if self._resume:
                        self._admit_unparked(wait=True)  # idle: block on tickets
                        continue
                    break
                inflight = self._step(self._host_toks())
                ticks += 1
                continue
            if self._can_speculate():
                # nothing donated: a held snapshot makes the speculative
                # step exactly revocable
                snap = (self._key, self.caches, self.positions.copy())
                spec = self._step(jnp.where(occupied(), inflight, 0))
                new_np = np.asarray(inflight)  # blocks on step t only
                if self._commit(new_np):
                    # surprise EOS: discard the in-flight step and re-enter
                    # with the recycled composition
                    self._key, self.caches, self.positions = snap
                    self.stats["rollbacks"] += 1
                    self.stats["steps"] -= 1
                    inflight = None
                else:
                    self.stats["overlapped"] += 1
                    inflight = spec
                    ticks += 1
            else:
                self._commit(np.asarray(inflight))
                inflight = None
        if inflight is not None:
            self._commit(np.asarray(inflight))
        return self.finished
