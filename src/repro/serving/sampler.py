"""Token sampling: greedy / temperature / top-k / top-p, jit-friendly."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0          # 0 = disabled
    top_p: float = 1.0      # 1 = disabled
    greedy: bool = False


def sample(key, logits, params: SamplingParams):
    """logits: [B, V] -> tokens [B] int32.

    Runs inside the engine's fused tick, so every branch is resolved at
    trace time from the (static) params — the common temperature=1.0 path
    lowers to a single categorical with no extra ops.
    """
    if params.greedy or params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if params.temperature != 1.0:
        logits = logits / jnp.maximum(params.temperature, 1e-6)
    if params.top_k > 0:
        kth = jax.lax.top_k(logits, params.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
