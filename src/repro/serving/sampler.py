"""Token sampling: greedy / temperature / top-k / top-p, jit-friendly.

Two entry points:

* :func:`sample` — one static :class:`SamplingParams` for the whole batch
  (legacy path; every branch resolves at trace time).
* :func:`sample_lanes` — per-lane parameters as stacked device arrays
  (:class:`LaneSampling`), so a greedy main lane and exploratory side lanes
  share ONE sampling dispatch inside the engine's fused/macro tick. Lanes
  with ``temperature <= 0`` reduce to exact ``argmax`` — independent of the
  PRNG key and of every other lane's parameters.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.ops import NEG_INF


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0          # 0 = disabled
    top_p: float = 1.0      # 1 = disabled
    greedy: bool = False


@dataclass
class LaneSampling:
    """Per-lane sampling parameters, stacked over the batch axis.

    Lives inside the engine's donated ``TickState`` so per-lane changes at
    admission time never recompile the tick. ``temperature <= 0`` marks a
    greedy lane; ``top_k == 0`` / ``top_p == 1`` disable those filters.
    """

    temperature: jax.Array  # [B] f32
    top_k: jax.Array        # [B] int32
    top_p: jax.Array        # [B] f32


jax.tree_util.register_dataclass(
    LaneSampling, data_fields=["temperature", "top_k", "top_p"], meta_fields=[]
)


def lane_params(params: SamplingParams, n: int) -> LaneSampling:
    """Broadcast one static SamplingParams to ``n`` lanes."""
    t = 0.0 if (params.greedy or params.temperature <= 0.0) else params.temperature
    return LaneSampling(
        temperature=jnp.full((n,), t, jnp.float32),
        top_k=jnp.full((n,), params.top_k, jnp.int32),
        top_p=jnp.full((n,), params.top_p, jnp.float32),
    )


def lane_values(params: SamplingParams) -> tuple[float, int, float]:
    """(temperature, top_k, top_p) scalars for one lane — the admission-time
    update path (fed through donated .at[lane].set jits)."""
    t = 0.0 if (params.greedy or params.temperature <= 0.0) else params.temperature
    return float(t), int(params.top_k), float(params.top_p)


def stack_lane_params(params_list) -> LaneSampling:
    """Stack a list of SamplingParams (one per lane) into a LaneSampling."""
    vals = [lane_values(p) for p in params_list]
    return LaneSampling(
        temperature=jnp.asarray([v[0] for v in vals], jnp.float32),
        top_k=jnp.asarray([v[1] for v in vals], jnp.int32),
        top_p=jnp.asarray([v[2] for v in vals], jnp.float32),
    )


def cat_lanes(*parts: LaneSampling) -> LaneSampling:
    return jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *parts)


class SampCache:
    """Memoized (stacked LaneSampling, use_filters, any_greedy) for a lane
    composition, with an explicit invalidation hook.

    Serving loops rebuild the stacked per-lane arrays only when the lane
    composition changes — admission, completion, and mid-window retirement
    must ALL call :meth:`invalidate`, because a stale cache silently reuses
    the previous request's sampling params on a recycled lane (and, through
    the static fast-path flags, can pin the whole batch to the wrong
    program). Central hook so no call site re-implements the pair."""

    def __init__(self):
        self._val = None

    @property
    def valid(self) -> bool:
        return self._val is not None

    def invalidate(self):
        self._val = None

    def get(self, lane_params):
        """``lane_params``: zero-arg callable returning the per-lane
        SamplingParams list; only consulted on a cache miss."""
        if self._val is None:
            ps = list(lane_params())
            self._val = (stack_lane_params(ps), *static_flags(ps))
        return self._val


def static_flags(params_iterable) -> tuple[bool, bool]:
    """(use_filters, any_greedy) for :func:`sample_lanes` over the given
    lanes' SamplingParams — THE definition of the static fast-path contract,
    shared by every caller so no site can drift to a different predicate."""
    ps = list(params_iterable)
    use_filters = any(p.top_k > 0 or p.top_p < 1.0 for p in ps)
    any_greedy = any(p.greedy or p.temperature <= 0.0 for p in ps)
    return use_filters, any_greedy


def sample(key, logits, params: SamplingParams):
    """logits: [B, V] -> tokens [B] int32.

    Runs inside the engine's fused tick, so every branch is resolved at
    trace time from the (static) params — the common temperature=1.0 path
    lowers to a single categorical with no extra ops.
    """
    if params.greedy or params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if params.temperature != 1.0:
        logits = logits / jnp.maximum(params.temperature, 1e-6)
    if params.top_k > 0:
        kth = jax.lax.top_k(logits, params.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_lanes(key, logits, lanes: LaneSampling, *, use_filters: bool = True,
                 any_greedy: bool = True):
    """logits: [B, V] -> tokens [B] int32, per-lane params as device arrays.

    One descending sort serves both filters: rank < top_k and cumulative
    probability *before* a token < top_p (the top-1 token always survives,
    so an over-tight top_p can never mask a whole row). The finite NEG_INF
    mask (shared with the Pallas kernels) keeps filtered rows NaN-free.
    Greedy lanes (temperature <= 0) select raw argmax via a lane-wise
    ``where`` — bit-identical to :func:`sample` with ``greedy=True`` and
    untouched by the stochastic lanes sharing the dispatch.

    ``use_filters``/``any_greedy`` are STATIC fast-path switches the caller
    derives from host-side knowledge of the lane params (the engine keeps
    per-lane mirrors): the descending sort is by far the dominant cost of
    sampling on CPU, and pure temperature/greedy batches don't need it.
    Callers must only clear a flag when no lane uses that feature — greedy
    lanes stay exact argmax under either setting of ``use_filters``, but
    stochastic draws differ bitwise between filtered and unfiltered
    programs (same distribution, different Gumbel assignment), so a flag
    may only change when lane params change (admission/drain boundaries).
    """
    B, V = logits.shape
    temps = lanes.temperature.astype(logits.dtype)
    # clamp tiny positive temperatures exactly like sample() does: without
    # it a denormal temperature overflows the scaled logits to inf and the
    # categorical draws among inf ties — temperature -> 0+ must converge to
    # argmax, not to tie-breaking noise (tests/test_sampler_edges.py)
    safe_t = jnp.where(temps > 0.0, jnp.maximum(temps, 1e-6), 1.0)
    scaled = logits / safe_t[:, None]
    if use_filters:
        order = jnp.argsort(-scaled, axis=-1)                   # descending
        ranked = jnp.take_along_axis(scaled, order, axis=-1)
        ranks = jnp.arange(V, dtype=jnp.int32)[None, :]
        k = jnp.where(lanes.top_k > 0, lanes.top_k, V)[:, None]
        keep_k = ranks < k
        # top_p nests inside top_k (same as sample(): the nucleus is taken
        # from the RENORMALIZED post-top-k distribution)
        ranked_k = jnp.where(keep_k, ranked, NEG_INF)
        probs = jax.nn.softmax(ranked_k, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep = keep_k & ((cum - probs) < lanes.top_p[:, None])
        keep = keep.at[:, 0].set(True)
        masked = jnp.where(keep, ranked, NEG_INF)
        choice = jax.random.categorical(key, masked, axis=-1)
        samp = jnp.take_along_axis(order, choice[:, None], axis=-1)[:, 0]
    else:
        samp = jax.random.categorical(key, scaled, axis=-1)
    if any_greedy:
        greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        samp = jnp.where(temps <= 0.0, greedy_tok, samp)
    return samp.astype(jnp.int32)
