"""The Prism (paper §3.2): singleton weight sharing.

One copy of the weights lives on device; every agent holds a *reference*.
In JAX this is natural (immutable device arrays are shared by reference);
the Prism makes it an enforced, accountable pattern: it owns the only
``device_put`` of the params and exposes exact byte accounting so the
Table-1/Table-2 memory claims are measurable, not vibes.

    M_total = Mem(W) + sum_i Mem(ctx_i)          (paper Eq. 1)
"""
from __future__ import annotations

import jax

from repro.models.config import ModelConfig


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


class Prism:
    """Singleton weight store. All agents read through `.params`."""

    def __init__(self, params, cfg: ModelConfig, sharding=None):
        if sharding is not None:
            params = jax.device_put(params, sharding)
        self._params = params
        self.cfg = cfg
        self._refs: set[str] = set()

    @property
    def params(self):
        return self._params

    def acquire(self, agent_id: str):
        """Register an agent; returns the shared params (no copy)."""
        self._refs.add(agent_id)
        return self._params

    def release(self, agent_id: str):
        self._refs.discard(agent_id)

    @property
    def n_agents(self) -> int:
        return len(self._refs)

    def weight_bytes(self) -> int:
        return tree_bytes(self._params)

    def memory_report(
        self,
        agent_cache_bytes: dict[str, int],
        *,
        store_report: dict | None = None,
        agents: dict[str, int] | None = None,
    ) -> dict:
        """Eq. 1 accounting: weights once + per-agent context.

        ``store_report`` (a :meth:`repro.memory.SynapseStore.report`) breaks
        the total out across the memory hierarchy: **hot** is the device
        context of the agents in ``agent_cache_bytes``, **warm**/**cold**
        are the host-RAM and on-disk bytes of hibernated agents — which by
        construction contribute zero device bytes. ``agents`` (a
        :meth:`repro.memory.AgentRegistry.counts`) records the
        registered-vs-active split the tier economics are about.
        """
        ctx = sum(agent_cache_bytes.values())
        rep = {
            "weight_bytes": self.weight_bytes(),
            "n_agents": len(agent_cache_bytes),
            "context_bytes_total": ctx,
            "context_bytes_per_agent": ctx / max(1, len(agent_cache_bytes)),
            "total_bytes": self.weight_bytes() + ctx,
            # counterfactual: each agent carrying its own weight copy
            "standard_architecture_bytes": len(agent_cache_bytes) * self.weight_bytes() + ctx,
        }
        if store_report is not None:
            rep["tiers"] = {
                "hot_bytes": ctx,  # live lanes on device
                "warm_bytes": store_report.get("warm_bytes", 0),
                "cold_bytes": store_report.get("cold_bytes", 0),
                "cold_raw_bytes": store_report.get("cold_raw_bytes", 0),
                "n_warm": store_report.get("n_warm", 0),
                "n_cold": store_report.get("n_cold", 0),
            }
        if agents is not None:
            rep["agents"] = dict(agents)
        return rep
