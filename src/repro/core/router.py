"""Cortex Router (paper §3.4): regex intent extraction on the decoded stream.

Host-side by design (it inspects sampled text, not device tensors). Triggers:
  [TASK: <description>]   -> spawn a side agent with <description> as prompt
  [DONE]                  -> side agent self-terminates
  [ANSWER: <text>]        -> side agent reports its thought
"""
from __future__ import annotations

import re
from dataclasses import dataclass

TASK_RE = re.compile(r"\[TASK:\s*([^\]]+)\]")
DONE_RE = re.compile(r"\[DONE\]")
ANSWER_RE = re.compile(r"\[ANSWER:\s*([^\]]+)\]")


@dataclass(frozen=True)
class Trigger:
    kind: str          # "task" | "done" | "answer"
    payload: str
    span: tuple[int, int]


class CortexRouter:
    """Incremental scanner: feed decoded text, get new triggers exactly once."""

    def __init__(self):
        self._scanned = {}

    def scan(self, agent_id: str, text: str) -> list[Trigger]:
        start = self._scanned.get(agent_id, 0)
        # rescan a small overlap so split tags across chunk boundaries match
        window_start = max(0, start - 256)
        triggers: list[Trigger] = []
        for m in TASK_RE.finditer(text, window_start):
            if m.end() > start:
                triggers.append(Trigger("task", m.group(1).strip(), m.span()))
        for m in DONE_RE.finditer(text, window_start):
            if m.end() > start:
                triggers.append(Trigger("done", "", m.span()))
        for m in ANSWER_RE.finditer(text, window_start):
            if m.end() > start:
                triggers.append(Trigger("answer", m.group(1).strip(), m.span()))
        self._scanned[agent_id] = len(text)
        triggers.sort(key=lambda t: t.span)
        return triggers

    def reset(self, agent_id: str):
        self._scanned.pop(agent_id, None)
