"""Cortex Router (paper §3.4): regex intent extraction on the decoded stream.

Host-side by design (it inspects sampled text, not device tensors). Triggers:
  [TASK: <description>]   -> spawn a side agent with <description> as prompt
  [DONE]                  -> side agent self-terminates
  [ANSWER: <text>]        -> side agent reports its thought
"""
from __future__ import annotations

import re
from dataclasses import dataclass

TASK_RE = re.compile(r"\[TASK:\s*([^\]]+)\]")
DONE_RE = re.compile(r"\[DONE\]")
ANSWER_RE = re.compile(r"\[ANSWER:\s*([^\]]+)\]")


@dataclass(frozen=True)
class Trigger:
    kind: str          # "task" | "done" | "answer"
    payload: str
    span: tuple[int, int]


class CortexRouter:
    """Incremental scanner: feed decoded text, get new triggers exactly once.

    Two APIs: :meth:`scan` takes the agent's FULL text each call (legacy);
    :meth:`feed` takes only the newly drained chunk and keeps a bounded
    overlap tail internally, so the per-drain cost is O(len(chunk))
    regardless of stream length — the fused engine's control-plane path.

    ``tail`` is the overlap kept between feeds so tags split across drain
    boundaries still match. The engine scales it with its macro-tick window
    (one drain per window feeds the whole window's decoded text in a single
    chunk). **Tail-size contract**: a tag longer than ``tail`` characters can
    straddle a drain boundary with its opening ``[`` already evicted from the
    retained overlap, and is then silently missed — so the engine must size
    ``tail`` at least as large as the longest tag it can round-trip
    (``[TASK: <side_prompt_cap bytes>]`` plus framing) and at least one full
    drain window of text (``8 * max_window`` bytes covers the worst-case
    UTF-8 expansion). tests/test_router.py pins both sides of this contract.

    :meth:`plausible` is the pipelined engine's trigger-plausibility hint: an
    unclosed ``[`` in the retained tail means the next drained chunk could
    complete a tag, so the adaptive-window policy must keep the window short
    and the pipelined drain must process that lane serially.
    """

    def __init__(self, tail: int = 256):
        self._tail = tail
        self._scanned = {}
        self._tails = {}  # agent_id -> (tail_text, absolute_offset_of_tail)

    def feed(self, agent_id: str, chunk: str) -> list[Trigger]:
        """Scan a newly drained chunk against the retained tail. Trigger
        spans are absolute offsets into the agent's full stream."""
        tail, base = self._tails.get(agent_id, ("", 0))
        text = tail + chunk
        scanned = self._scanned.get(agent_id, 0)
        triggers: list[Trigger] = []
        for regex, kind, payload in (
            (TASK_RE, "task", True), (DONE_RE, "done", False), (ANSWER_RE, "answer", True),
        ):
            for m in regex.finditer(text):
                if base + m.end() > scanned:
                    triggers.append(
                        Trigger(kind, m.group(1).strip() if payload else "",
                                (base + m.start(), base + m.end()))
                    )
        end = base + len(text)
        self._scanned[agent_id] = end
        keep = min(len(text), self._tail)
        self._tails[agent_id] = (text[len(text) - keep:], end - keep)
        triggers.sort(key=lambda t: t.span)
        return triggers

    def scan(self, agent_id: str, text: str) -> list[Trigger]:
        """Full-text convenience wrapper: feeds only the unseen suffix."""
        seen = self._scanned.get(agent_id, 0)
        return self.feed(agent_id, text[min(seen, len(text)):])

    def plausible(self, agent_id: str) -> bool:
        """True when the retained tail ends with an unclosed ``[`` — i.e. a
        trigger tag may be in flight across the drain boundary. Conservative
        by construction: every tag this router matches needs a ``[`` before
        its closing ``]``, so ``plausible() == False`` plus a bracket-free
        next chunk guarantees :meth:`feed` on that chunk returns nothing."""
        tail, _ = self._tails.get(agent_id, ("", 0))
        return "[" in tail[tail.rfind("]") + 1:]

    def reset(self, agent_id: str):
        self._scanned.pop(agent_id, None)
        self._tails.pop(agent_id, None)

    def export_state(self, agent_id: str) -> dict | None:
        """Plain-data snapshot of one agent's scan state (tail + offsets) —
        persisted with its hibernation blob so crash recovery restores a
        tag split across the hibernate boundary, not just the caches."""
        if agent_id not in self._scanned and agent_id not in self._tails:
            return None
        tail, base = self._tails.get(agent_id, ("", 0))
        return {"scanned": self._scanned.get(agent_id, 0), "tail": tail, "base": base}

    def restore_state(self, agent_id: str, state: dict) -> None:
        self._scanned[agent_id] = int(state.get("scanned", 0))
        self._tails[agent_id] = (state.get("tail", ""), int(state.get("base", 0)))
