"""Referential Injection (paper §3.6).

A side agent's accepted thought is encoded by a forward pass (shared
weights — the Prism) and its per-layer K/V are appended to the main agent's
caches at *virtual* RoPE positions, so the main stream's token sequence and
positions are untouched: the model "remembers" the thought without reading
it. Static-shape adaptation (DESIGN.md §3): caches are pre-allocated; full
caches receive injected K/V at the write cursor, synapse caches in their
dedicated ``inj_*`` slots.

For attention-free layers (RWKV6 / Mamba2 state), injection is re-expressed
as a *state blend*: the thought is run forward and its terminal recurrent
state is mixed into the main state (beta-weighted). This is the closest
TPU/SSM-idiomatic equivalent — documented as an adaptation in DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import gate as gate_lib
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.models.config import ModelConfig


def encode_thought_kv(params, cfg: ModelConfig, thought_tokens, virtual_pos):
    """Run a forward pass over the thought and capture per-layer K/V.

    thought_tokens: [B, T] int32; virtual_pos: [B] — the virtual positional
    index assigned to the thought (paper: "auxiliary context").
    Returns the ModelCaches of a throwaway prefill with capacity == T, whose
    full caches hold exactly the rotated K/V of the thought, plus the
    terminal hidden state [B, d] (used by the Validation Gate).
    """
    B, T = thought_tokens.shape
    positions = virtual_pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    if cfg.rope_kind == "mrope":
        positions = jnp.broadcast_to(positions[:, None, :], (B, 3, T))
    spec = model_lib.CacheSpec(kind="full", capacity=T)
    caches = model_lib.init_caches(cfg, B, spec)
    logits, hidden, caches = model_lib.prefill(
        params, cfg, {"tokens": thought_tokens, "positions": positions}, caches, spec=spec
    )
    return caches, hidden


def _append_lanes(dst, src, start, axis: int):
    """Per-lane dynamic append: dst [L,B,S,...], src [L,B,T,...], start [B]."""
    def per_lane(d, s, st):  # d: [L,S,...], s: [L,T,...]
        return jax.lax.dynamic_update_slice_in_dim(d, s.astype(d.dtype), st, axis=axis)
    return jax.vmap(per_lane, in_axes=(1, 1, 0), out_axes=1)(dst, src, start)


def inject_full(main: cache_lib.FullCache, thought: cache_lib.FullCache, accept):
    """Append thought K/V into a stacked FullCache group.

    main.*: [L, B, S, ...]; thought.*: [L, B, T, ...]; accept: [B] bool.
    The injected slots get the thought's (virtual) positions; length grows by
    T for accepted lanes.
    """
    T = thought.k.shape[2]
    start = main.length[0]  # [B] — all layers share lane lengths
    new_k = _append_lanes(main.k, thought.k, start, axis=1)
    new_v = _append_lanes(main.v, thought.v, start, axis=1)
    new_pos = _append_lanes(main.pos, thought.pos, start, axis=1)
    new_score = _append_lanes(main.score, thought.score, start, axis=1)
    acc = accept[None, :, None, None, None]
    sel = lambda n, o: jnp.where(jnp.reshape(accept, (1, -1) + (1,) * (n.ndim - 2)), n, o)
    new_len = jnp.where(accept, main.length + T, main.length)
    return cache_lib.FullCache(
        k=sel(new_k, main.k),
        v=sel(new_v, main.v),
        pos=sel(new_pos, main.pos),
        score=sel(new_score, main.score),
        length=jnp.broadcast_to(new_len, main.length.shape),
    )


def inject_mla(main: cache_lib.MLACache, thought: cache_lib.MLACache, accept):
    T = thought.ckv.shape[2]
    start = main.length[0]
    new_ckv = _append_lanes(main.ckv, thought.ckv, start, axis=1)
    new_krope = _append_lanes(main.krope, thought.krope, start, axis=1)
    new_score = _append_lanes(main.score, thought.score, start, axis=1)
    sel = lambda n, o: jnp.where(jnp.reshape(accept, (1, -1) + (1,) * (n.ndim - 2)), n, o)
    new_len = jnp.where(accept, main.length + T, main.length)
    return cache_lib.MLACache(
        ckv=sel(new_ckv, main.ckv),
        krope=sel(new_krope, main.krope),
        score=sel(new_score, main.score),
        length=jnp.broadcast_to(new_len, main.length.shape),
    )


def inject_synapse(main: cache_lib.SynapseCache, thought: cache_lib.FullCache, accept, max_tokens: int | None = None):
    """Write thought K/V into the synapse's dedicated injection slots.

    Thought tokens beyond the J slots are dropped oldest-first (the slots are
    a ring). thought.*: [L, B, T, ...] from encode_thought_kv.
    """
    J = main.inj_k.shape[2]
    T = thought.k.shape[2]
    take = min(T, J)
    th_k = thought.k[:, :, -take:]
    th_v = thought.v[:, :, -take:]
    th_pos = thought.pos[:, :, -take:]
    start = jnp.minimum(main.inj_count[0], J - take)  # [B]
    new_k = _append_lanes(main.inj_k, th_k, start, axis=1)
    new_v = _append_lanes(main.inj_v, th_v, start, axis=1)
    new_pos = _append_lanes(main.inj_pos, th_pos, start, axis=1)
    sel = lambda n, o: jnp.where(jnp.reshape(accept, (1, -1) + (1,) * (n.ndim - 2)), n, o)
    new_count = jnp.where(accept, jnp.minimum(main.inj_count + take, J), main.inj_count)
    return dataclasses.replace(
        main,
        inj_k=sel(new_k, main.inj_k),
        inj_v=sel(new_v, main.inj_v),
        inj_pos=sel(new_pos, main.inj_pos),
        inj_count=jnp.broadcast_to(new_count, main.inj_count.shape),
    )


def blend_state(main_state, thought_state, accept, beta: float = 0.3):
    """SSM adaptation: mix the thought's terminal recurrent state into the
    main agent's state. main/thought: stacked [L, B, ...] state pytrees."""
    def mix(m, t):
        acc = jnp.reshape(accept, (1, -1) + (1,) * (m.ndim - 2))
        blended = (1.0 - beta) * m.astype(jnp.float32) + beta * t.astype(jnp.float32)
        return jnp.where(acc, blended.astype(m.dtype), m)
    return jax.tree.map(mix, main_state, thought_state)


def merge_thought(
    params,
    cfg: ModelConfig,
    main_caches,
    main_hidden,
    thought_tokens,
    virtual_pos,
    lane_mask,
    theta: float,
    beta: float = 0.3,
):
    """Encode + Validation Gate + Referential Injection as ONE fused step.

    The legacy merge path issued three dispatches (encode_thought_kv, gate,
    inject); fused, a merge costs a single drain-time dispatch with the main
    caches donated. Note the gate decision is a traced value, so the thought
    prefill and the masked inject are always computed — a rejected merge is
    cheaper in dispatches, not in FLOPs (a host-side early-out would need
    the gate score synced back first).
    Returns (new_main_caches, accept [B] bool, score [B] f32).
    """
    thought_caches, t_hidden = encode_thought_kv(params, cfg, thought_tokens, virtual_pos)
    accept_vec, score = gate_lib.validate(main_hidden, t_hidden, theta)
    accept = accept_vec & lane_mask
    new_caches = inject(cfg, main_caches, thought_caches, accept, beta)
    return new_caches, accept, score


def inject(cfg: ModelConfig, main_caches, thought_caches, accept, beta: float = 0.3):
    """Dispatch injection across the whole stack. Both cache trees must come
    from the same cfg (same group structure)."""
    new_groups = []
    for grp, m, t in zip(cfg.layer_groups(), main_caches.groups, thought_caches.groups):
        if grp.kind == "attn":
            if isinstance(m, cache_lib.MLACache):
                new_groups.append(inject_mla(m, t, accept))
            elif isinstance(m, cache_lib.SynapseCache):
                new_groups.append(inject_synapse(m, t, accept))
            else:
                new_groups.append(inject_full(m, t, accept))
        else:
            new_groups.append(blend_state(m, t, accept, beta))
    shared = main_caches.shared
    if shared is not None and thought_caches.shared is not None:
        if isinstance(shared, cache_lib.SynapseCache):
            shared = inject_synapse(shared, thought_caches.shared, accept)
        else:
            shared = inject_full(shared, thought_caches.shared, accept)
    return model_lib.ModelCaches(groups=tuple(new_groups), shared=shared)
