"""Validation Gate (paper §3.5, Eq. 2).

Geometric quality control: a side thought is merged only if the cosine
similarity between its terminal hidden state and the main agent's current
hidden state clears a threshold theta (paper default 0.5). Prevents
"hallucination cascades" from polluting the main stream.
"""
from __future__ import annotations

import jax.numpy as jnp


def cosine_score(h_main, t_side, eps: float = 1e-8):
    """Eq. 2: h_main, t_side: [B, d] -> [B] f32."""
    a = h_main.astype(jnp.float32)
    b = t_side.astype(jnp.float32)
    num = jnp.sum(a * b, axis=-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + eps
    return num / den


def validate(h_main, t_side, theta: float = 0.5):
    """Returns (accept [B] bool, score [B] f32)."""
    score = cosine_score(h_main, t_side)
    return score >= theta, score
