"""The Cortex Engine — River & Stream topology on TPU (DESIGN.md §3).

The paper runs the main agent ("River") and side agents ("Streams") on
concurrent CUDA streams. The TPU-native equivalent implemented here:

* ONE Prism (shared weights) — no per-agent copies (paper §3.2).
* Main agents are lanes of a batched full-cache ``decode_step``; side agents
  are lanes of a batched synapse-cache ``decode_step``. Each engine `tick`
  advances both batches by one fused step — concurrency through batching,
  priority through admission policy (main lanes are always stepped; side
  lanes only while active).
* Logical asynchrony is preserved: a side agent reasons over the landmark
  snapshot taken at spawn time (token t-k) while the river continues past t.
* Spawn = hybrid landmark compression of the parent's cache (paper §3.3);
  merge = Validation Gate (§3.5) then Referential Injection (§3.6).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gate as gate_lib
from repro.core import injection
from repro.core import synapse as synapse_lib
from repro.core.prism import Prism, tree_bytes
from repro.core.router import CortexRouter, Trigger
from repro.data.tokenizer import ByteTokenizer
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.sampler import SamplingParams, sample


def _lane_slice(tree, lane: int):
    """Select batch lane (axis 1 — axis 0 is the stacked layer dim)."""
    return jax.tree.map(lambda a: a[:, lane], tree)


def _lane_write(dst, src_tree, dst_lane: int, src_lane: int):
    """dst[:, dst_lane] <- src[:, src_lane] across a stacked cache pytree."""
    return jax.tree.map(lambda d, s: d.at[:, dst_lane].set(s[:, src_lane].astype(d.dtype)), dst, src_tree)


def spawn_caches(cfg: ModelConfig, main_caches: model_lib.ModelCaches, spec: model_lib.CacheSpec):
    """Compress a main agent's caches into fresh side-agent synapse caches.

    Attention groups: hybrid landmark compression (density = the cache's
    accumulated attention mass). SSM groups: the state is already O(1) — the
    side agent receives a copy (zero marginal context, noted in DESIGN.md).
    MLA: the latent cache is compressed by landmark selection on the latent
    point cloud is future work; sides receive the latent cache as-is.
    """
    groups = []
    for grp, c in zip(cfg.layer_groups(), main_caches.groups):
        if grp.kind == "attn" and isinstance(c, cache_lib.FullCache):
            comp = jax.vmap(
                lambda layer_cache: synapse_lib.compress(
                    cfg, layer_cache, None, spec.n_landmarks, spec.window, spec.n_inject, spec.policy
                )
            )(c)
            groups.append(comp)
        else:
            groups.append(c)
    shared = main_caches.shared
    if shared is not None and isinstance(shared, cache_lib.FullCache):
        shared = jax.vmap(
            lambda layer_cache: synapse_lib.compress(
                cfg, layer_cache, None, spec.n_landmarks, spec.window, spec.n_inject, spec.policy
            )
        )(shared)
    return model_lib.ModelCaches(groups=tuple(groups), shared=shared)


@dataclass
class AgentView:
    """Host-side bookkeeping for one agent lane."""

    agent_id: str
    lane: int
    kind: str                  # "main" | "side"
    parent_lane: int = -1
    task: str = ""
    text: str = ""
    tokens: list = field(default_factory=list)
    position: int = 0          # next rope position
    active: bool = False
    steps: int = 0
    pending_prompt: list = field(default_factory=list)
    prompt_len: int = 0


class CortexEngine:
    def __init__(
        self,
        prism: Prism,
        tokenizer: ByteTokenizer,
        *,
        n_main: int = 1,
        max_side: int = 8,
        main_capacity: int = 1024,
        side_spec: model_lib.CacheSpec | None = None,
        theta: float = 0.5,
        inject_tokens: int = 16,
        side_max_steps: int = 64,
        sampling: SamplingParams = SamplingParams(temperature=0.8),
        seed: int = 0,
    ):
        self.prism = prism
        self.cfg = prism.cfg
        self.tok = tokenizer
        self.router = CortexRouter()
        self.theta = theta
        self.inject_tokens = inject_tokens
        self.side_max_steps = side_max_steps
        self.sampling = sampling
        self._key = jax.random.key(seed)

        self.main_spec = model_lib.CacheSpec(kind="full", capacity=main_capacity)
        self.side_spec = side_spec or model_lib.CacheSpec(
            kind="synapse", n_landmarks=64, window=64, n_inject=inject_tokens
        )
        self.n_main, self.max_side = n_main, max_side
        self.main_caches = model_lib.init_caches(self.cfg, n_main, self.main_spec)
        self.side_caches = model_lib.init_caches(self.cfg, max_side, self.side_spec)
        self.mains = [AgentView(f"main{i}", i, "main") for i in range(n_main)]
        self.sides = [AgentView(f"side{i}", i, "side") for i in range(max_side)]
        self.main_hidden = jnp.zeros((n_main, self.cfg.d_model), jnp.float32)
        self.side_hidden = jnp.zeros((max_side, self.cfg.d_model), jnp.float32)
        self.history: list[dict] = []

        cfg = self.cfg
        self._jit_prefill_main = jax.jit(
            lambda p, toks, c: model_lib.prefill(p, cfg, {"tokens": toks}, c, spec=self.main_spec)
        )
        self._jit_decode_main = jax.jit(
            lambda p, toks, pos, c: model_lib.decode_step(
                p, cfg, {"tokens": toks, "positions": pos}, c, spec=self.main_spec
            )
        )
        self._jit_decode_side = jax.jit(
            lambda p, toks, pos, c: model_lib.decode_step(
                p, cfg, {"tokens": toks, "positions": pos}, c, spec=self.side_spec
            )
        )
        self._jit_spawn = jax.jit(lambda c: spawn_caches(cfg, c, self.side_spec))
        self._jit_encode = jax.jit(
            lambda p, toks, vpos: injection.encode_thought_kv(p, cfg, toks, vpos)
        )
        self._jit_inject = jax.jit(
            lambda mc, tc, accept: injection.inject(cfg, mc, tc, accept)
        )

    # ------------------------------------------------------------------
    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def submit(self, prompt: str, lane: int = 0):
        """Start (or restart) a main agent on `lane` with `prompt`."""
        ids = self.tok.encode(prompt, bos=True)
        toks = jnp.asarray([ids], jnp.int32)
        # prefill writes lanes batched; run on a single-lane cache then copy in
        lane_cache = jax.tree.map(lambda a: a[:, lane : lane + 1], self.main_caches)
        logits, hidden, lane_cache = self._jit_prefill_main(self.prism.params, toks, lane_cache)
        self.main_caches = jax.tree.map(
            lambda full, part: full.at[:, lane : lane + 1].set(part), self.main_caches, lane_cache
        )
        m = self.mains[lane]
        m.text, m.tokens = prompt, list(ids)
        m.position, m.active, m.steps = len(ids), True, 0
        self.main_hidden = self.main_hidden.at[lane].set(hidden[0])
        self.prism.acquire(m.agent_id)
        return m

    # ------------------------------------------------------------------
    def _step_main(self):
        active = [m for m in self.mains if m.active]
        if not active:
            return
        toks = jnp.asarray([m.tokens[-1] if m.tokens else 0 for m in self.mains], jnp.int32)
        pos = jnp.asarray([m.position for m in self.mains], jnp.int32)
        logits, hidden, new_caches = self._jit_decode_main(
            self.prism.params, toks, pos, self.main_caches
        )
        new_tok = sample(self._next_key(), logits, self.sampling)
        new_tok_np = np.asarray(new_tok)
        for m in self.mains:
            if not m.active:
                continue
            t = int(new_tok_np[m.lane])
            m.tokens.append(t)
            m.text += self.tok.decode([t])
            m.position += 1
            m.steps += 1
        self.main_caches = new_caches
        self.main_hidden = hidden

    # ------------------------------------------------------------------
    def _free_side_lane(self) -> int:
        for s in self.sides:
            if not s.active:
                return s.lane
        return -1

    def _spawn_side(self, parent: AgentView, task: str):
        lane = self._free_side_lane()
        if lane < 0:
            return None  # admission policy: drop when streams are saturated
        compressed = self._jit_spawn(self.main_caches)
        self.side_caches = _lane_write(self.side_caches, compressed, lane, parent.lane)
        s = self.sides[lane]
        s.task, s.text = task, ""
        s.parent_lane = parent.lane
        s.tokens = self.tok.encode(f"[TASK: {task}]")
        s.position = parent.position  # continues the stream's positional frame
        s.active, s.steps = True, 0
        s.pending_prompt = list(s.tokens)  # teacher-forced before free generation
        s.prompt_len = len(s.tokens)
        self.prism.acquire(s.agent_id)
        self.history.append({"event": "spawn", "agent": s.agent_id, "task": task})
        return s

    def _step_sides(self):
        if not any(s.active for s in self.sides):
            return
        toks, pos = [], []
        for s in self.sides:
            if s.active and getattr(s, "pending_prompt", None):
                toks.append(s.pending_prompt.pop(0))
            elif s.active and s.tokens:
                toks.append(s.tokens[-1])
            else:
                toks.append(0)
            pos.append(s.position if s.active else 0)
        logits, hidden, new_caches = self._jit_decode_side(
            self.prism.params,
            jnp.asarray(toks, jnp.int32),
            jnp.asarray(pos, jnp.int32),
            self.side_caches,
        )
        new_tok = np.asarray(sample(self._next_key(), logits, self.sampling))
        self.side_caches = new_caches
        self.side_hidden = hidden
        finished = []
        for s in self.sides:
            if not s.active:
                continue
            s.position += 1
            s.steps += 1
            if s.pending_prompt:
                continue  # still consuming the task prompt
            t = int(new_tok[s.lane])
            s.tokens.append(t)
            s.text += self.tok.decode([t])
            trig = [tr for tr in self.router.scan(s.agent_id, s.text) if tr.kind in ("done", "answer")]
            generated = s.steps - getattr(s, "prompt_len", 0)
            if trig or generated >= self.side_max_steps:
                finished.append((s, next((tr.payload for tr in trig if tr.kind == "answer"), s.text)))
        for s, thought in finished:
            self._merge_side(s, thought)

    # ------------------------------------------------------------------
    def _merge_side(self, s: AgentView, thought: str):
        parent = self.mains[s.parent_lane]
        ids = self.tok.encode(thought)[-self.inject_tokens :]
        ids = ids + [self.tok.pad_id] * (self.inject_tokens - len(ids))
        toks = jnp.tile(jnp.asarray(ids, jnp.int32)[None], (self.n_main, 1))
        vpos = jnp.asarray([m.position for m in self.mains], jnp.int32)  # virtual index
        thought_caches, t_hidden = self._jit_encode(self.prism.params, toks, vpos)
        accept_vec, score = gate_lib.validate(
            self.main_hidden, t_hidden, self.theta
        )
        lane_mask = jnp.arange(self.n_main) == s.parent_lane
        accept = accept_vec & lane_mask
        accepted = bool(np.asarray(accept)[s.parent_lane])
        if accepted:
            self.main_caches = self._jit_inject(self.main_caches, thought_caches, accept)
            parent.position += 0  # stream positions untouched (referential)
        self.history.append(
            {
                "event": "merge",
                "agent": s.agent_id,
                "accepted": accepted,
                "gate_score": float(np.asarray(score)[s.parent_lane]),
                "thought": thought[:80],
            }
        )
        self.router.reset(s.agent_id)
        self.prism.release(s.agent_id)
        s.active = False

    # ------------------------------------------------------------------
    def tick(self):
        """One scheduler tick: river step, router scan, stream step."""
        self._step_main()
        for m in self.mains:
            if not m.active:
                continue
            for tr in self.router.scan(m.agent_id, m.text):
                if tr.kind == "task":
                    self._spawn_side(m, tr.payload)
        self._step_sides()

    def run(self, n_ticks: int):
        for _ in range(n_ticks):
            self.tick()

    # ------------------------------------------------------------------
    def memory_report(self) -> dict:
        per_agent = {}
        for m in self.mains:
            if m.active:
                per_agent[m.agent_id] = tree_bytes(_lane_slice(self.main_caches, m.lane))
        for s in self.sides:
            if s.active:
                per_agent[s.agent_id] = tree_bytes(_lane_slice(self.side_caches, s.lane))
        return self.prism.memory_report(per_agent)
