"""The Cortex Engine — fused-tick River & Stream topology (DESIGN.md §3).

The paper runs the main agent ("River") and side agents ("Streams") on
concurrent CUDA streams. The TPU-native equivalent is a *device-resident
scheduler hot loop*:

* ONE Prism (shared weights) — no per-agent copies (paper §3.2).
* ONE jitted dispatch per tick: ``fused_tick`` advances the main-lane batch
  (full caches), the side-lane batch (synapse caches), and the on-device
  samplers in a single donated call over a :class:`TickState` pytree. Cache
  buffers are donated, so a tick updates them in place instead of doubling
  peak memory.
* ZERO blocking host syncs per tick: sampled tokens are written into small
  on-device ring buffers and drained to the host only every ``sync_every``
  ticks (or lazily via :meth:`CortexEngine.drain` / ``memory_report``). The
  router scan, spawn, and merge logic run against the drained buffer at that
  boundary — host-side control at 1/sync_every the rate of device steps.
* MACRO TICKS: since nothing leaves the device between drains, the whole
  ``sync_every`` window is ONE dispatch — ``fused_tick(n_ticks=W)`` scans
  the per-tick body over the window inside a single jitted, donated program,
  emitting the token rings for the full window. :meth:`CortexEngine.run(n)`
  therefore issues ``ceil(n / sync_every)`` dispatches instead of ``n``.
* PIPELINED DRAINS (two-deep pipeline): ``run(n)`` fetches window *t*'s
  rings (the ONE blocking transfer per window), then — when a cheap
  conservative gate on the raw ring bytes proves window *t* cannot carry a
  router trigger or side completion — dispatches window *t+1* BEFORE doing
  window *t*'s host post-processing, so UTF-8 decoding, router regex scans,
  and bookkeeping overlap the device's execution of the next window. The
  gate never misses a control event (triggers need a ``[``/``]`` byte pair,
  side step budgets are host-computable), so spawn/merge timing — and hence
  every token — is bitwise identical to the serial dispatch→drain→dispatch
  order. A failed gate simply falls back to that serial order for one
  window; user-facing control calls (``submit``/``retire_side``/``drain``)
  flush the in-flight window before mutating state.
* ADAPTIVE WINDOWS: :class:`AdaptiveWindow` lengthens the scan window
  (``sync_every`` × {1, 2, 4, …} up to ``max_window`` — a small fixed set of
  lazily jit-cached scan lengths) while drains stay quiet, and snaps back to
  the base window on any trigger, spawn, merge, or admission. Windows are
  capped exactly at the serial-path boundary where an active side's step
  budget completes, and the router's :meth:`~repro.core.router.CortexRouter.
  plausible` hint (an unclosed ``[`` near the stream end) forces a short
  window — so control ops land on the same virtual tick as the pinned-window
  engine.
* Per-lane sampling: temperature/top-k/top-p live as stacked device arrays
  (:class:`repro.serving.sampler.LaneSampling`) inside ``TickState``, so a
  greedy river can coexist with exploratory streams in the same dispatch and
  admission-time changes never recompile the tick.
* Side-agent prompts are teacher-forced from an on-device prompt buffer
  (``side_prompt``/``side_plen``/``side_step``), so a freshly spawned stream
  needs no host involvement until its next drain.
* Spawn = hybrid landmark compression of the *parent lane only* (paper
  §3.3), via the fused ``kernels.ops.landmark_score`` sweep; merge =
  Validation Gate (§3.5) + Referential Injection (§3.6) fused into one
  dispatch (``injection.merge_thought``).

Performance invariants (asserted by tests/test_fused_tick.py,
tests/test_macro_tick.py, and tests/test_adaptive_pipeline.py):
  * ``tick()`` issues exactly ONE jitted dispatch;
  * ``run(n)`` issues exactly ``ceil(n / sync_every)`` jitted dispatches
    with a pinned window, and **at most** that many with adaptation on;
  * no blocking host transfer happens outside ``drain()``/``_fetch_rings``;
  * each drain performs exactly one device→host pull of the token rings,
    and the overlapped post-processing region issues ZERO transfers (it
    runs under ``jax.transfer_guard("disallow")`` in the tests);
  * greedy lanes are bitwise identical between the pipelined/adaptive path,
    the serial macro path, and the single-tick path, across spawn/merge
    interleavings, and unaffected by other lanes' sampling params.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import injection
from repro.core import synapse as synapse_lib
from repro.core import synapse_sharded as sharded_lib
from repro.core.prism import Prism, tree_bytes
from repro.core.router import CortexRouter
from repro.data.tokenizer import ByteTokenizer
from repro.kernels.ops import ring_append
from repro.launch.sharding import lane_gather, lane_scatter
from repro.memory import (
    ACTIVE,
    HIBERNATED,
    LOST,
    REGISTERED,
    AgentRegistry,
    SnapshotLostError,
    SynapseStore,
)
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.serving.sampler import (
    LaneSampling, SamplingParams, cat_lanes, lane_params, lane_values,
    sample_lanes, static_flags,
)


def _lane_slice(tree, lane: int):
    """Select batch lane (axis 1 — axis 0 is the stacked layer dim)."""
    return jax.tree.map(lambda a: a[:, lane], tree)


def spawn_caches(cfg: ModelConfig, main_caches: model_lib.ModelCaches, spec: model_lib.CacheSpec):
    """Compress a main agent's caches into fresh side-agent synapse caches.

    Attention groups: hybrid landmark compression with the density term from
    the fused ``kernels.ops.landmark_score`` sweep. The paper's Q_t (the
    parent's current query) is approximated by the most recent resident key,
    pooled over kv heads and broadcast to the query heads — q and k of the
    newest token are projections of the same hidden state, so its key is the
    best per-layer stand-in available post-hoc. The stacked layer axis is
    folded into the batch axis, so all layers compress in ONE kernel sweep
    instead of a vmap of L separate passes.

    SSM groups: the state is already O(1) — the side agent receives a copy
    (zero marginal context, noted in DESIGN.md). MLA: latent landmark
    selection is future work; sides receive the latent cache as-is.
    """
    groups = []
    for grp, c in zip(cfg.layer_groups(), main_caches.groups):
        if grp.kind == "attn" and isinstance(c, cache_lib.FullCache):
            groups.append(_compress_stacked(cfg, c, spec))
        else:
            groups.append(c)
    shared = main_caches.shared
    if shared is not None and isinstance(shared, cache_lib.FullCache):
        shared = _compress_stacked(cfg, shared, spec)
    return model_lib.ModelCaches(groups=tuple(groups), shared=shared)


def _compress_stacked(cfg: ModelConfig, c: cache_lib.FullCache, spec: model_lib.CacheSpec):
    """[L, B, ...] FullCache -> [L, B, ...] SynapseCache, layers folded into
    the batch axis (one fused scoring sweep for the whole stack)."""
    L, B = c.pos.shape[:2]
    flat = jax.tree.map(lambda a: a.reshape((L * B,) + a.shape[2:]), c)
    last = jnp.clip(flat.length - 1, 0, flat.k.shape[1] - 1)
    k_last = jnp.take_along_axis(flat.k, last[:, None, None, None], axis=1)[:, 0]  # [LB, Hkv, D]
    g = cfg.n_heads // k_last.shape[1]
    q_proxy = jnp.repeat(k_last, g, axis=1)  # [LB, H, D] — Q_t ~ K_t proxy
    comp = synapse_lib.compress(
        cfg, flat, q_proxy, spec.n_landmarks, spec.window, spec.n_inject, spec.policy
    )
    return jax.tree.map(lambda a: a.reshape((L, B) + a.shape[1:]), comp)


# ---------------------------------------------------------------------------
# device-resident tick state
# ---------------------------------------------------------------------------
@dataclass
class TickState:
    """Everything ``fused_tick`` reads and writes — one donated pytree."""

    key: jax.Array          # PRNG state
    cursor: jax.Array       # [] int32 — ring write index (ticks since drain)
    # river lanes
    main_tok: jax.Array     # [M] int32 — last token per lane
    main_pos: jax.Array     # [M] int32 — next rope position
    main_active: jax.Array  # [M] bool
    main_hidden: jax.Array  # [M, d] f32 — gate input
    main_ring: jax.Array    # [M, R] int32 — sampled tokens awaiting drain (-1 = none)
    main_samp: LaneSampling  # [M] per-lane temperature/top-k/top-p
    main_caches: model_lib.ModelCaches
    # stream lanes
    side_tok: jax.Array     # [S] int32
    side_pos: jax.Array     # [S] int32
    side_active: jax.Array  # [S] bool
    side_step: jax.Array    # [S] int32 — ticks since spawn
    side_plen: jax.Array    # [S] int32 — teacher-forced prompt length
    side_prompt: jax.Array  # [S, P] int32 — on-device prompt buffer
    side_hidden: jax.Array  # [S, d] f32
    side_ring: jax.Array    # [S, R] int32
    side_samp: LaneSampling  # [S] per-lane temperature/top-k/top-p
    side_caches: model_lib.ModelCaches


jax.tree_util.register_dataclass(
    TickState, data_fields=[f for f in TickState.__dataclass_fields__], meta_fields=[]
)


def init_tick_state(
    cfg: ModelConfig,
    *,
    n_main: int,
    max_side: int,
    main_spec: model_lib.CacheSpec,
    side_spec: model_lib.CacheSpec,
    ring_capacity: int,
    side_prompt_cap: int,
    main_sampling: SamplingParams,
    side_sampling: SamplingParams,
    seed: int = 0,
) -> TickState:
    """Fresh TickState for an engine (module-level so launch tooling can
    ``jax.eval_shape`` the exact state the engine would build — the dry-run
    lowers the 1024-lane macro tick without materializing 1024 caches)."""
    d = cfg.d_model
    M, S, R, P = n_main, max_side, ring_capacity, side_prompt_cap
    return TickState(
        key=jax.random.key(seed, impl="rbg"),  # cheap per-tick key chain on CPU
        cursor=jnp.zeros((), jnp.int32),
        main_tok=jnp.zeros((M,), jnp.int32),
        main_pos=jnp.zeros((M,), jnp.int32),
        main_active=jnp.zeros((M,), bool),
        main_hidden=jnp.zeros((M, d), jnp.float32),
        main_ring=jnp.full((M, R), -1, jnp.int32),
        main_samp=lane_params(main_sampling, M),
        main_caches=model_lib.init_caches(cfg, M, main_spec),
        side_tok=jnp.zeros((S,), jnp.int32),
        side_pos=jnp.zeros((S,), jnp.int32),
        side_active=jnp.zeros((S,), bool),
        side_step=jnp.zeros((S,), jnp.int32),
        side_plen=jnp.zeros((S,), jnp.int32),
        side_prompt=jnp.zeros((S, P), jnp.int32),
        side_hidden=jnp.zeros((S, d), jnp.float32),
        side_ring=jnp.full((S, R), -1, jnp.int32),
        side_samp=lane_params(side_sampling, S),
        side_caches=model_lib.init_caches(cfg, S, side_spec),
    )


def _one_tick(
    params,
    state: TickState,
    *,
    cfg: ModelConfig,
    main_spec: model_lib.CacheSpec,
    side_spec: model_lib.CacheSpec,
    step_sides: bool = True,
    use_filters: bool = True,
    any_greedy: bool = True,
) -> TickState:
    """One scheduler tick, entirely on device: main-lane decode, side-lane
    decode (synapse caches, Pallas attend), per-lane sampling, ring append.

    Inactive lanes decode garbage harmlessly (their cursors are frozen and
    their caches are fully rewritten on admission) — concurrency through
    batching, priority through the active masks. ``step_sides=False``
    compiles the river-only variant the engine uses while no stream is
    active (side activity only changes at drain boundaries, so the host
    knows which variant applies without reading device state).
    """
    key, k_tick = jax.random.split(state.key)
    m_act = state.main_active
    s_act = state.side_active
    M = m_act.shape[0]

    # ---- river step ----
    logits_m, hidden_m, main_caches = model_lib.decode_step(
        params, cfg, {"tokens": state.main_tok, "positions": state.main_pos},
        state.main_caches, spec=main_spec,
    )

    if step_sides:
        # teacher-force the on-device task prompt, then free-run from the
        # last sampled token; the sampled token "counts" from the last
        # forced step on.
        forced = state.side_step < state.side_plen
        pidx = jnp.clip(state.side_step, 0, state.side_prompt.shape[1] - 1)
        prompt_tok = jnp.take_along_axis(state.side_prompt, pidx[:, None], axis=1)[:, 0]
        in_tok = jnp.where(s_act, jnp.where(forced, prompt_tok, state.side_tok), 0)
        in_pos = jnp.where(s_act, state.side_pos, 0)
        logits_s, hidden_s, side_caches = model_lib.decode_step(
            params, cfg, {"tokens": in_tok, "positions": in_pos},
            state.side_caches, spec=side_spec,
        )
        # one per-lane sampling pass over all lanes (one key chain per tick)
        samp = sample_lanes(
            k_tick, jnp.concatenate([logits_m, logits_s], axis=0),
            cat_lanes(state.main_samp, state.side_samp),
            use_filters=use_filters, any_greedy=any_greedy,
        )
        samp_m, samp_s = samp[:M], samp[M:]
    else:
        samp_m = sample_lanes(
            k_tick, logits_m, state.main_samp,
            use_filters=use_filters, any_greedy=any_greedy,
        )

    # river-lane state transition (shared by both variants)
    ring_m = jnp.where(m_act, samp_m, -1)
    new_state = dataclasses.replace(
        state,
        key=key,
        cursor=state.cursor + 1,
        main_tok=jnp.where(m_act, samp_m, state.main_tok),
        main_pos=state.main_pos + m_act.astype(jnp.int32),
        main_hidden=hidden_m.astype(jnp.float32),
        main_ring=ring_append(state.main_ring, ring_m, state.cursor),
        main_caches=main_caches,
    )
    if not step_sides:
        return new_state

    keep = s_act & (state.side_step >= state.side_plen - 1)
    ring_s = jnp.where(keep, samp_s, -1)
    return dataclasses.replace(
        new_state,
        side_tok=jnp.where(keep, samp_s, state.side_tok),
        side_pos=state.side_pos + s_act.astype(jnp.int32),
        side_step=state.side_step + s_act.astype(jnp.int32),
        side_hidden=hidden_s.astype(jnp.float32),
        side_ring=ring_append(state.side_ring, ring_s, state.cursor),
        side_caches=side_caches,
    )


def fused_tick(
    params,
    state: TickState,
    *,
    cfg: ModelConfig,
    main_spec: model_lib.CacheSpec,
    side_spec: model_lib.CacheSpec,
    step_sides: bool = True,
    use_filters: bool = True,
    any_greedy: bool = True,
    n_ticks: int = 1,
) -> TickState:
    """``n_ticks`` scheduler ticks in ONE device program.

    ``n_ticks == 1`` is the classic fused tick. ``n_ticks > 1`` is the
    macro tick: a ``jax.lax.scan`` of the per-tick body over the whole
    ``sync_every`` window, so the host re-enters XLA once per window
    instead of once per virtual tick. The PRNG key splits once per virtual
    tick inside the scan — the exact chain of the single-tick path — so
    token streams are bitwise identical regardless of how ticks are grouped
    into dispatches. The ring cursor is part of the carry; the rings must
    have capacity for ``state.cursor + n_ticks`` entries.
    """
    step = partial(
        _one_tick, params, cfg=cfg, main_spec=main_spec, side_spec=side_spec,
        step_sides=step_sides, use_filters=use_filters, any_greedy=any_greedy,
    )
    if n_ticks == 1:
        return step(state)

    def body(st, _):
        return step(st), None

    out, _ = jax.lax.scan(body, state, None, length=n_ticks)
    return out


# ---------------------------------------------------------------------------
# small donated state-transition helpers (drain-time only). They take ONLY
# the small per-lane field arrays — never the cache trees, whose buffers may
# already be donated to the prefill/spawn/merge dispatch of the same event.
# ---------------------------------------------------------------------------
def _admit_main_fields(tok_a, pos_a, act_a, hid_a, samp_a, lane, tok, pos, hidden, temp, tk, tp):
    return (
        tok_a.at[lane].set(tok),
        pos_a.at[lane].set(pos),
        act_a.at[lane].set(True),
        hid_a.at[lane].set(hidden.astype(hid_a.dtype)),
        _set_lane_samp(samp_a, lane, temp, tk, tp),
    )


def _admit_side_fields(prompt_a, plen_a, step_a, tok_a, pos_a, act_a, samp_a, lane, prompt, plen, step, last_tok, pos, temp, tk, tp):
    # ``step`` is 0 on a fresh spawn; a wake passes the hibernated snapshot's
    # step so the teacher-forcing cursor resumes exactly where it stopped
    return (
        prompt_a.at[lane].set(prompt),
        plen_a.at[lane].set(plen),
        step_a.at[lane].set(step),
        tok_a.at[lane].set(last_tok),
        pos_a.at[lane].set(pos),
        act_a.at[lane].set(True),
        _set_lane_samp(samp_a, lane, temp, tk, tp),
    )


def _set_lane_samp(samp_a: LaneSampling, lane, temp, tk, tp) -> LaneSampling:
    return LaneSampling(
        temperature=samp_a.temperature.at[lane].set(temp),
        top_k=samp_a.top_k.at[lane].set(tk),
        top_p=samp_a.top_p.at[lane].set(tp),
    )


def _spawn_lane(cfg: ModelConfig, side_spec, main_caches, side_caches, parent_lane, side_lane):
    """Compress ONE parent lane and scatter it into ONE side lane — no
    all-lane vmap, no full-tree copies (the legacy path compressed every
    main lane to use one)."""
    parent = jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, parent_lane, 1, axis=1), main_caches
    )
    comp = spawn_caches(cfg, parent, side_spec)
    return jax.tree.map(
        lambda d, s: jax.lax.dynamic_update_slice_in_dim(d, s.astype(d.dtype), side_lane, axis=1),
        side_caches,
        comp,
    )


# ---------------------------------------------------------------------------
# hibernation snapshots (ISSUE 7): one lane's device state, gathered into a
# replicated dict pytree the SynapseStore can park on the host. Greedy decode
# depends only on a lane's own cache/token/position, so restoring these exact
# bytes into ANY free lane reproduces the agent's token stream bitwise.
# ---------------------------------------------------------------------------
def _gather_main_lane(state: TickState, lane):
    return {
        "caches": lane_gather(state.main_caches, lane, axis=1),
        "tok": state.main_tok[lane],
        "pos": state.main_pos[lane],
        "hidden": state.main_hidden[lane],
    }


def _gather_side_lane(state: TickState, lane):
    return {
        "caches": lane_gather(state.side_caches, lane, axis=1),
        "tok": state.side_tok[lane],
        "pos": state.side_pos[lane],
        "step": state.side_step[lane],
        "plen": state.side_plen[lane],
        "prompt": state.side_prompt[lane],
        "hidden": state.side_hidden[lane],
    }


# byte values the conservative drain gate inspects on the raw token rings
# (ByteTokenizer: ids 0..255 are raw bytes; every router tag needs them both)
_OPEN_BRACKET, _CLOSE_BRACKET = ord("["), ord("]")


class AdaptiveWindow:
    """Window-length policy: lengthen ``sync_every`` while drains are quiet.

    Proposals come from a small fixed ladder ``base * {1, 2, 4, ...}`` capped
    at ``max_window``, so the engine's lazily jit-cached scan-length variants
    stay bounded (one compile per rung, ever). The policy climbs one rung per
    quiet drain — no router trigger, no spawn/merge/completion, no admission
    — and snaps back to the base window on any such event, restoring the
    trigger-reaction latency of the pinned engine the moment control traffic
    reappears. ``max_window == base`` degenerates to the pinned policy.
    """

    def __init__(self, base: int, max_window: int | None = None):
        self.base = max(1, base)
        requested = max(self.base, max_window or self.base)
        # every rung must be base * 2^k: the engine's boundary math (side
        # budget caps, drain alignment with the pinned reference) assumes
        # windows are base multiples, so a max_window that is not on the
        # ladder rounds DOWN to the largest rung below it
        ladder = [self.base]
        while ladder[-1] * 2 <= requested:
            ladder.append(ladder[-1] * 2)
        self.ladder = tuple(ladder)
        self.max_window = ladder[-1]
        self._rung = 0

    @property
    def adaptive(self) -> bool:
        return len(self.ladder) > 1

    def propose(self) -> int:
        return self.ladder[self._rung]

    def on_quiet_drain(self):
        self._rung = min(self._rung + 1, len(self.ladder) - 1)

    def on_event(self):
        self._rung = 0


@dataclass
class AgentView:
    """Host-side bookkeeping for one agent lane (refreshed at drain time)."""

    agent_id: str
    lane: int
    kind: str                  # "main" | "side"
    parent_lane: int = -1
    task: str = ""
    text: str = ""
    tokens: list = field(default_factory=list)
    position: int = 0          # next rope position (drain-time mirror)
    active: bool = False
    steps: int = 0
    prompt_len: int = 0


# the durable subset of AgentView: what crash recovery needs to rebuild the
# host-side view of a hibernated agent (lane/active are rebuilt at wake)
_VIEW_META_FIELDS = (
    "agent_id", "kind", "parent_lane", "task", "text", "tokens",
    "position", "steps", "prompt_len",
)


def _view_to_meta(view: "AgentView") -> dict:
    out = {f: getattr(view, f) for f in _VIEW_META_FIELDS}
    out["tokens"] = [int(t) for t in out["tokens"]]
    return out


def _view_from_meta(meta: dict) -> "AgentView":
    view = AgentView(meta["agent_id"], -1, meta["kind"])
    for f in _VIEW_META_FIELDS[2:]:
        setattr(view, f, meta[f])
    view.tokens = list(meta["tokens"])
    view.active = False
    return view


class CortexEngine:
    def __init__(
        self,
        prism: Prism,
        tokenizer: ByteTokenizer,
        *,
        n_main: int = 1,
        max_side: int = 8,
        main_capacity: int = 1024,
        side_spec: model_lib.CacheSpec | None = None,
        theta: float = 0.5,
        inject_tokens: int = 16,
        side_max_steps: int = 64,
        sampling: SamplingParams = SamplingParams(temperature=0.8),
        side_sampling: SamplingParams | None = None,
        seed: int = 0,
        sync_every: int = 1,
        max_window: int | None = None,
        pipeline: bool = True,
        side_prompt_cap: int = 64,
        compute_dtype: str | None = None,
        mesh=None,
        store: SynapseStore | None = None,
        hibernate_idle_ticks: int | None = None,
        wake_deadline_s: float | None = None,
    ):
        """``mesh``: a lane mesh (see ``launch.mesh.make_lane_mesh``) shards
        every side-lane TickState leaf over its ``lane`` axis and runs the
        macro tick under ``shard_map`` — side agents scale with the mesh
        while main-stream state stays replicated (each device steps the
        river redundantly; rivers are the cheap part of the topology).
        ``max_side`` must be a multiple of the lane-axis size. Greedy token
        streams are bitwise identical to the ``mesh=None`` engine; every
        dispatch/donation/zero-sync invariant holds unchanged."""
        self.prism = prism
        cfg = prism.cfg
        # Serving dtype policy: CPU has no native bf16 — XLA emulates it with
        # up/down converts on every op, strictly slower than f32. Auto-pick
        # f32 there; accelerator backends keep the configured dtype.
        if compute_dtype is None and cfg.compute_dtype == "bfloat16" and jax.default_backend() == "cpu":
            compute_dtype = "float32"
        if compute_dtype is not None:
            cfg = dataclasses.replace(cfg, compute_dtype=compute_dtype)
        self.cfg = cfg
        self.tok = tokenizer
        self.theta = theta
        self.inject_tokens = inject_tokens
        self.side_max_steps = side_max_steps
        self.sampling = sampling
        self.side_sampling = side_sampling if side_sampling is not None else sampling
        self.sync_every = max(1, sync_every)
        self.side_prompt_cap = side_prompt_cap
        # Adaptive windows: ``run`` may scan up to max_window virtual ticks
        # per dispatch while drains stay quiet (max_window=None pins the
        # window at sync_every; off-ladder values round DOWN to base*2^k).
        # ``pipeline=False`` keeps the serial PR 4 dispatch→drain→dispatch
        # order — the parity reference in tests — whose windows stay pinned,
        # so adaptation is dropped there rather than paying max_window-sized
        # rings and router tail for a policy that never engages.
        self.window = AdaptiveWindow(
            self.sync_every, max_window if pipeline else None
        )
        self.max_window = self.window.max_window
        self.pipeline = pipeline
        # macro windows mean bigger drain chunks: size the router's overlap
        # tail so a tag split across window boundaries still matches. The
        # tail must cover (a) the longest tag the engine round-trips — a
        # side_prompt_cap-byte task payload plus '[TASK: ]' framing — and
        # (b) a full drain window of text (8 bytes/token bounds the worst
        # UTF-8 replacement expansion). tests/test_router.py pins this.
        self.router = CortexRouter(
            tail=max(256, 8 * self.max_window, side_prompt_cap + 16)
        )

        # lane mesh: detect the axis up front — the side spec's attend policy
        # depends on it (threaded through the CacheSpec, not a module global)
        self.mesh = mesh
        self.lane_axis = None
        if mesh is not None and "lane" in getattr(mesh, "axis_names", ()):
            self.lane_axis = "lane"
            lanes = mesh.shape["lane"]
            if max_side % lanes != 0:
                raise ValueError(
                    f"max_side={max_side} must be a multiple of the lane-axis "
                    f"size {lanes} (every side leaf shards the same lane dim)"
                )

        self.main_spec = model_lib.CacheSpec(kind="full", capacity=main_capacity)
        base_side_spec = side_spec or model_lib.CacheSpec(
            kind="synapse", n_landmarks=64, window=64, n_inject=inject_tokens
        )
        if self.lane_axis is not None and base_side_spec.policy.attend_impl == "pallas":
            # under the lane shard_map each device attends over its LOCAL
            # lanes: route through piece_attend, whose local path is the
            # same fused kernels.ops attend — bitwise parity preserved
            base_side_spec = dataclasses.replace(
                base_side_spec,
                policy=dataclasses.replace(base_side_spec.policy, attend_impl="piece"),
            )
        self.side_spec = base_side_spec
        self.n_main, self.max_side = n_main, max_side
        self.mains = [AgentView(f"main{i}", i, "main") for i in range(n_main)]
        self.sides = [AgentView(f"side{i}", i, "side") for i in range(max_side)]
        # tiered memory (ISSUE 7): agents outlive lane slots — hibernated
        # contexts park in the store (warm host RAM / cold zstd disk), the
        # registry owns identity + LRU bookkeeping, and wakes land via the
        # async prefetch tickets committed at window boundaries in run()
        self.store = store if store is not None else SynapseStore()
        self.registry = AgentRegistry()
        self.hibernate_idle_ticks = hibernate_idle_ticks
        # default promotion deadline (seconds) applied to every wake unless
        # overridden per call — bounds how long a stuck prefetch can hold an
        # agent in limbo before it degrades to a failed wake
        self.wake_deadline_s = wake_deadline_s
        self._agent_seq = 0
        self._wake_tickets: dict[str, object] = {}
        self._pending_wakes: list[str] = []
        # (kind, lane) pairs woken between a ring fetch and that window's
        # post-processing: they were NOT on device for the fetched window,
        # so the mirror advancement in _postprocess must skip them
        self._fresh_wakes: set[tuple[str, int]] = set()
        # host mirrors of the per-lane device sampling arrays: they pick the
        # STATIC sampler fast path (skip the sort when no live lane filters,
        # skip the argmax select when none is greedy) without device reads
        self._main_sp: list[SamplingParams] = [self.sampling] * n_main
        self._side_sp: list[SamplingParams] = [self.side_sampling] * max_side
        # per-agent stateful UTF-8 decoders (ISSUE 9 bugfix): drain chunks
        # decode incrementally, so a codepoint split across a window
        # boundary never becomes U+FFFD in the agent's `text`. Keyed by
        # agent_id — the state survives hibernate/wake in-process, and its
        # pending bytes ride the hibernation metadata for crash recovery.
        self._decoders: dict[str, object] = {}
        # serving front-end hooks (ISSUE 9): ``stream_tap(view, chunk,
        # toks)`` fires during drain post-processing for every lane that
        # received tokens (chunks are incremental-decoder output — their
        # concatenation is the bitwise text stream); ``admission_hook`` runs
        # with the window-boundary control plane in :meth:`_boundary_ops`,
        # so front-end admissions never flush a pipelined window.
        self.stream_tap = None
        self.admission_hook = None
        self.history: list[dict] = []
        self.stats = {
            "ticks": 0, "tick_dispatches": 0, "macro_dispatches": 0,
            "aux_dispatches": 0, "host_syncs": 0, "drains": 0,
            # pipeline/adaptive telemetry: drains whose host post-processing
            # overlapped the next window's device execution, and a histogram
            # of dispatched window lengths (window_hist[w] = count)
            "overlapped_drains": 0, "window_hist": {},
            # tiered-memory telemetry
            "hibernates": 0, "wakes": 0,
            # resilience telemetry (ISSUE 8): wake_failures = transient
            # (snapshot intact, agent stays HIBERNATED, retryable);
            # lost_agents = permanent (snapshot unrecoverable, agent LOST);
            # recoveries = hibernated agents re-adopted after a restart
            "wake_failures": 0, "lost_agents": 0, "recoveries": 0,
        }
        self._pending = 0  # ticks since last drain (== device ring cursor)

        cfg = self.cfg
        # Serving-dtype weights, cast ONCE (the per-dispatch cast_params
        # inside decode becomes an identity XLA elides). The Prism's master
        # copy stays authoritative for accounting/training.
        self._params = model_lib.cast_params(prism.params, cfg)
        # rings must hold the longest adaptive window, not just sync_every
        self.state = init_tick_state(
            cfg, n_main=n_main, max_side=max_side, main_spec=self.main_spec,
            side_spec=self.side_spec, ring_capacity=self.max_window,
            side_prompt_cap=side_prompt_cap, main_sampling=self.sampling,
            side_sampling=self.side_sampling, seed=seed,
        )

        # lane placement: side leaves shard over the mesh, main/key/cursor
        # and the weights replicate. Committing everything up front keeps
        # the macro dispatch transfer-free (the zero-host-sync invariant).
        self._rep_sharding = None
        self._state_specs = None
        if self.lane_axis is not None:
            from repro.launch import sharding as shard_rules

            self._state_specs = shard_rules.tick_state_specs(self.state, mesh)
            self._state_shardings = shard_rules.shardings_for(self._state_specs, mesh)
            self._rep_sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()
            )
            self.state = jax.device_put(self.state, self._state_shardings)
            self._params = jax.device_put(self._params, self._rep_sharding)

        # Small stacks trace faster through lax.scan but *run* faster
        # unrolled on CPU (no while-loop thunks, cross-layer fusion); deep
        # stacks keep scan so HLO size stays depth-independent.
        jcfg = dataclasses.replace(cfg, scan_layers=cfg.scan_layers and cfg.n_layers > 8)

        # ONE fused dispatch per tick (or per macro window: fused_tick with
        # n_ticks > 1 scans the tick body); the whole TickState is donated,
        # so caches (the dominant buffers) update in place. The river-only
        # variant is dispatched while no stream lane is live. Window-length
        # variants (full windows + the trailing partial window of a run)
        # compile lazily, cached by (n_ticks, step_sides, sampler flags).
        self._jcfg = jcfg
        self._jit_macro: dict[tuple[int, bool, bool, bool], object] = {}

        # drain-time jits. On a lane mesh every output sharding is pinned
        # explicitly (replicated or the TickState leaf's lane spec) so the
        # donated buffers alias and the next macro dispatch sees exactly the
        # shardings it compiled for — GSPMD would otherwise be free to pick
        # different output shardings and break donation or force resharding.
        rep, ssh = self._rep_sharding, getattr(self, "_state_shardings", None)

        def _jit(fn, donate, out=None):
            if self.lane_axis is not None and out is not None:
                return jax.jit(fn, donate_argnums=donate, out_shardings=out)
            return jax.jit(fn, donate_argnums=donate)

        self._jit_prefill_lane = _jit(
            lambda p, toks, c, lane: model_lib.prefill_lane(
                p, jcfg, {"tokens": toks}, c, lane, spec=self.main_spec
            ),
            (2,),
            (rep, rep, ssh.main_caches) if ssh else None,
        )
        self._jit_spawn = _jit(
            partial(_spawn_lane, jcfg, self.side_spec), (1,),
            ssh.side_caches if ssh else None,
        )
        self._jit_merge = _jit(
            lambda p, mc, mh, toks, vpos, mask: injection.merge_thought(
                p, jcfg, mc, mh, toks, vpos, mask, self.theta
            ),
            (1,),
            (ssh.main_caches, rep, rep) if ssh else None,
        )
        self._jit_admit_main = _jit(
            _admit_main_fields, (0, 1, 2, 3, 4),
            (ssh.main_tok, ssh.main_pos, ssh.main_active, ssh.main_hidden,
             ssh.main_samp) if ssh else None,
        )
        self._jit_admit_side = _jit(
            _admit_side_fields, (0, 1, 2, 3, 4, 5, 6),
            (ssh.side_prompt, ssh.side_plen, ssh.side_step, ssh.side_tok,
             ssh.side_pos, ssh.side_active, ssh.side_samp) if ssh else None,
        )
        self._jit_retire_side = _jit(
            lambda act_a, lane: act_a.at[lane].set(False), (0,),
            ssh.side_active if ssh else None,
        )
        self._jit_retire_main = _jit(
            lambda act_a, lane: act_a.at[lane].set(False), (0,),
            ssh.main_active if ssh else None,
        )
        # hibernate/wake lane transfer jits. Gathers replicate their outputs
        # (on a mesh GSPMD inserts the collective pulling a sharded side
        # lane's leaves together); scatters donate the full cache tree and
        # pin its lane sharding so the next macro dispatch aliases cleanly.
        self._jit_gather_main = _jit(_gather_main_lane, (), rep if ssh else None)
        self._jit_gather_side = _jit(_gather_side_lane, (), rep if ssh else None)
        self._jit_wake_main_caches = _jit(
            lambda c, part, lane: lane_scatter(c, part, lane, axis=1), (0,),
            ssh.main_caches if ssh else None,
        )
        self._jit_wake_side_caches = _jit(
            lambda c, part, lane: lane_scatter(c, part, lane, axis=1), (0,),
            ssh.side_caches if ssh else None,
        )
        self._jit_set_side_hidden = _jit(
            lambda hid_a, lane, h: hid_a.at[lane].set(h.astype(hid_a.dtype)), (0,),
            ssh.side_hidden if ssh else None,
        )

    def _macro_fn(self, n_ticks: int, step_sides: bool, use_filters: bool, any_greedy: bool):
        """Jitted fused_tick variant for an ``n_ticks``-long window.

        On a lane mesh the whole window body runs under ``shard_map``: each
        device scans its local side-lane shard (caches, rings, sampling
        arrays, budgets) while stepping the replicated river redundantly —
        still ONE donated dispatch, still zero host syncs. The PRNG key is a
        replicated carry, so greedy lanes stay bitwise identical to the
        single-device engine no matter how lanes are placed."""
        key = (n_ticks, step_sides, use_filters, any_greedy)
        if key not in self._jit_macro:
            fn = partial(
                fused_tick, cfg=self._jcfg, main_spec=self.main_spec,
                side_spec=self.side_spec, step_sides=step_sides,
                use_filters=use_filters, any_greedy=any_greedy,
                n_ticks=n_ticks,
            )
            if self.lane_axis is not None:
                fn = sharded_lib.shard_map_nocheck(
                    fn, self.mesh,
                    in_specs=(jax.sharding.PartitionSpec(), self._state_specs),
                    out_specs=self._state_specs,
                )
            self._jit_macro[key] = jax.jit(fn, donate_argnums=(1,))
        return self._jit_macro[key]

    def _sampler_flags(self, step_sides: bool) -> tuple[bool, bool]:
        """(use_filters, any_greedy) over the lanes the dispatch samples.

        Derived purely from the host mirrors, so the flags — and thus the
        chosen program — only change when lane params or activity change,
        which happens at drain boundaries: macro and single-tick paths pick
        identical variants (stochastic draws differ bitwise between
        variants, so this invariance is what keeps parity exact)."""
        ps = [self._main_sp[m.lane] for m in self.mains if m.active]
        if step_sides:
            ps += [self._side_sp[s.lane] for s in self.sides if s.active]
        return static_flags(ps)

    @property
    def lane_mesh_shape(self) -> tuple[int, ...] | None:
        """Device-mesh shape when lane-sharded (recorded by the benches)."""
        if self.mesh is None:
            return None
        return tuple(int(s) for s in self.mesh.devices.shape)

    # -- per-agent incremental UTF-8 decode --------------------------------
    def _decoder(self, agent_id: str):
        dec = self._decoders.get(agent_id)
        if dec is None:
            dec = self._decoders[agent_id] = self.tok.stream_decoder()
        return dec

    def agent_text(self, agent_id: str) -> str:
        """The agent's full decoded text as of the last drain, INCLUDING the
        would-be flush of a codepoint left incomplete at the window
        boundary — i.e. exactly what ``tok.decode(tokens)`` yields for the
        same stream. Non-destructive: the decoder keeps buffering, so the
        live stream stays bitwise when the missing bytes arrive."""
        for v in (*self.mains, *self.sides):
            if v.agent_id == agent_id:
                dec = self._decoders.get(agent_id)
                return v.text + (dec.tail() if dec is not None else "")
        rec = self.registry.get(agent_id)
        view = rec.saved["view"] if rec.saved else None
        if view is None:
            raise KeyError(agent_id)
        dec = self._decoders.get(agent_id)
        return view.text + (dec.tail() if dec is not None else "")

    # -- legacy views over the device state --------------------------------
    @property
    def main_caches(self):
        return self.state.main_caches

    @property
    def side_caches(self):
        return self.state.side_caches

    @property
    def main_hidden(self):
        return self.state.main_hidden

    @property
    def side_hidden(self):
        return self.state.side_hidden

    # ------------------------------------------------------------------
    def submit(self, prompt: str, lane: int = 0, sampling: SamplingParams | None = None,
               agent_id: str | None = None):
        """Start (or restart) a main agent on `lane` with `prompt`.

        Prefills directly into the batched cache at `lane` (one dispatch,
        donated caches — no gather/scatter round-trip of the full tree).
        ``sampling`` overrides the engine default for THIS lane only (e.g. a
        greedy river among exploratory lanes); restarting a lane resets it.
        ``agent_id`` names the agent in the registry (it can later
        :meth:`hibernate` and :meth:`wake` into a different lane); omitted,
        the classic per-lane identity ``main{lane}`` is used when free."""
        self.drain()  # align host mirrors to a window boundary
        self.window.on_event()  # admission: back to the base window
        aid = self._claim_main_identity(lane, agent_id)
        ids = self.tok.encode(prompt, bos=True)
        toks = jnp.asarray([ids], jnp.int32)
        logits, hidden, new_caches = self._jit_prefill_lane(
            self._params, toks, self.state.main_caches, lane
        )
        self._main_sp[lane] = sampling if sampling is not None else self.sampling
        temp, tk, tp = lane_values(self._main_sp[lane])
        tok_a, pos_a, act_a, hid_a, samp_a = self._jit_admit_main(
            self.state.main_tok, self.state.main_pos, self.state.main_active,
            self.state.main_hidden, self.state.main_samp,
            lane, ids[-1], len(ids), hidden[0], temp, tk, tp,
        )
        self.state = dataclasses.replace(
            self.state, main_caches=new_caches, main_tok=tok_a, main_pos=pos_a,
            main_active=act_a, main_hidden=hid_a, main_samp=samp_a,
        )
        self.stats["aux_dispatches"] += 2
        m = AgentView(aid, lane, "main")
        self.mains[lane] = m
        m.text, m.tokens = prompt, list(ids)
        m.position, m.active, m.steps = len(ids), True, 0
        m.prompt_len = len(ids)
        self._decoders[aid] = self.tok.stream_decoder()  # fresh byte stream
        self.prism.acquire(m.agent_id)
        rec = self.registry.bind(aid, lane)
        rec.bound_tick = self.stats["ticks"]
        self.router.reset(m.agent_id)  # lane may be restarting
        # triggers already present in the prompt spawn immediately
        for tr in self.router.feed(m.agent_id, prompt):
            if tr.kind == "task":
                self._spawn_side(m, tr.payload)
        return m

    def _claim_main_identity(self, lane: int, agent_id: str | None) -> str:
        """Resolve the agent_id a main-lane submit binds, evicting the lane's
        previous occupant from the registry (its context is overwritten)."""
        cur = self.mains[lane]
        if cur.active:
            # whoever held the lane loses its device context
            self.prism.release(cur.agent_id)
            self.registry.release(cur.agent_id)
            self.router.reset(cur.agent_id)
            self._decoders.pop(cur.agent_id, None)
        if agent_id is None:
            agent_id = f"main{lane}"
            if agent_id in self.registry and (
                self.registry.get(agent_id).status == HIBERNATED
                or (self.registry.get(agent_id).status == ACTIVE
                    and self.registry.get(agent_id).lane != lane)
            ):
                # the classic identity is alive elsewhere (parked or woken
                # into another lane): mint a fresh one instead of clobbering
                agent_id = f"main{lane}.{self._agent_seq}"
                self._agent_seq += 1
        else:
            if agent_id in self.registry:
                rec = self.registry.get(agent_id)
                if rec.status == ACTIVE and rec.lane != lane:
                    raise ValueError(
                        f"agent {agent_id!r} is already active on lane {rec.lane}"
                    )
                if rec.status == HIBERNATED:
                    # re-submitting replaces the parked context outright
                    self.store.drop(agent_id)
                    self._wake_tickets.pop(agent_id, None)
                    if agent_id in self._pending_wakes:
                        self._pending_wakes.remove(agent_id)
        self.registry.register(agent_id, "main")
        return agent_id

    def submit_agent(self, prompt: str, agent_id: str | None = None,
                     sampling: SamplingParams | None = None):
        """Lane-less submit: place a (new or registered) agent on any free
        main lane, hibernating the least-recently-touched resident if the
        river lanes are full — "max lanes" becomes "max *active* agents"."""
        lane = self._free_main_lane()
        if lane < 0:
            evicted = self._evict_lru_main()
            if evicted is None:
                raise RuntimeError(
                    "no free main lane and no evictable resident "
                    "(all mains have live side streams)"
                )
            lane = self._free_main_lane()
            assert lane >= 0
        if agent_id is None:
            agent_id = f"agent{self._agent_seq}"
            self._agent_seq += 1
        return self.submit(prompt, lane=lane, sampling=sampling, agent_id=agent_id)

    # ------------------------------------------------------------------
    def _any_active(self) -> bool:
        return any(m.active for m in self.mains) or any(s.active for s in self.sides)

    def tick(self):
        """One scheduler tick: exactly one jitted dispatch, no host sync.

        Spawns/merges/router triggers are handled at drain boundaries —
        every `sync_every` ticks. Side activity only changes at those
        boundaries, so the host picks the right tick variant for free."""
        if not self._any_active():
            self.stats["ticks"] += 1
            return  # idle engine: nothing to decode, nothing to drain
        self._dispatch_window(1)
        if self._pending >= self.sync_every:
            self.drain()

    def macro_tick(self):
        """One macro tick: `sync_every` virtual ticks in ONE jitted, donated
        dispatch (a lax.scan over the fused tick body), then the window
        drains. The device never syncs with the host inside the window."""
        if not self._any_active():
            self.stats["ticks"] += self.sync_every
            return
        if self._pending:
            self.drain()  # align the ring cursor to a window boundary
        self._dispatch_window(self.sync_every)
        self.drain()

    def _dispatch_window(self, n: int):
        """Advance ``n <= max_window - pending`` virtual ticks in one
        dispatch. No drain, no host sync — callers close the window."""
        assert self._pending + n <= self.max_window
        step_sides = any(s.active for s in self.sides)
        fn = self._macro_fn(n, step_sides, *self._sampler_flags(step_sides))
        self.state = fn(self._params, self.state)
        self.stats["ticks"] += n
        self.stats["tick_dispatches"] += 1
        if n > 1:
            self.stats["macro_dispatches"] += 1
        hist = self.stats["window_hist"]
        hist[n] = hist.get(n, 0) + 1
        self._pending += n

    def _next_window(self, remaining: int, pending=None) -> int:
        """Length of the next scan window: the adaptive proposal, capped (a)
        exactly at the serial-path boundary where any active side's step
        budget completes — a multiple of the base window, so the merge lands
        on the same virtual tick as the pinned engine — and (b) to the base
        window whenever the router's retained tail holds an unclosed ``[``
        (a tag may be completing: keep reaction latency at one base window).
        Every cap keeps the window a multiple of the base except the run's
        trailing partial window (``remaining``).

        ``pending=(rings, n)`` is the overlapped-branch correction: window
        *t* has been fetched but NOT yet post-processed, so the side views'
        ``tokens``/``steps`` are one window stale — the budget cap must
        count window *t*'s recorded ring tokens or the boundary lands one
        window late and the merge drifts off the serial tick (the router
        tail, by contrast, is provably unchanged by a gate-approved window:
        no ``[`` entered it and no pending ``[`` was closed)."""
        base = self.sync_every
        w = self.window.propose()
        if w > base:
            for s in self.sides:
                if not s.active:
                    continue
                generated = len(s.tokens) - s.prompt_len
                steps = s.steps
                if pending is not None:
                    rings, p_n = pending
                    toks = rings[1][s.lane, :p_n]
                    generated += int((toks >= 0).sum())
                    steps += p_n
                forced_left = max(0, (s.prompt_len - 1) - steps)
                t_budget = forced_left + max(1, self.side_max_steps - generated)
                boundary = base * -(-t_budget // base)  # ceil to base multiple
                w = min(w, boundary)
            if any(
                self.router.plausible(a.agent_id)
                for a in (*self.mains, *self.sides) if a.active
            ):
                w = base
        return min(w, remaining)

    def _gate(self, rings, n: int) -> bool:
        """May window ``t+1`` be dispatched BEFORE window ``t``'s host
        post-processing? True only when that post-processing provably issues
        no control op (spawn/merge/completion) — i.e. it is pure host
        bookkeeping. Conservative, byte-level, and cheap (numpy on the
        already-fetched rings):

        * any ``[`` in a lane's new tokens could open (and close) a tag —
          unsafe;
        * a ``]`` completes a tag only if the retained router tail has an
          unclosed ``[`` (:meth:`CortexRouter.plausible`) — unsafe;
        * a side lane reaching its step budget this window merges — exact
          host arithmetic, unsafe.

        False negatives are impossible (every trigger needs those bytes;
        budgets are deterministic), so a True verdict guarantees bitwise
        parity with the serial drain order."""
        main_ring, side_ring = rings
        for m in self.mains:
            if not m.active:
                continue
            toks = main_ring[m.lane, :n]
            toks = toks[toks >= 0]
            if (toks == _OPEN_BRACKET).any():
                return False
            if (toks == _CLOSE_BRACKET).any() and self.router.plausible(m.agent_id):
                return False
        for s in self.sides:
            if not s.active:
                continue
            toks = side_ring[s.lane, :n]
            toks = toks[toks >= 0]
            if (toks == _OPEN_BRACKET).any():
                return False
            if (toks == _CLOSE_BRACKET).any() and self.router.plausible(s.agent_id):
                return False
            if len(s.tokens) - s.prompt_len + toks.size >= self.side_max_steps:
                return False
        return True

    def run(self, n_ticks: int):
        """Advance ``n_ticks`` virtual ticks in at most
        ``ceil(n_ticks/sync_every)`` dispatches (exactly that many with a
        pinned window; adaptive windows need fewer).

        Pipelined (default): after fetching window *t*'s rings — the one
        blocking sync per window — the conservative :meth:`_gate` decides
        whether window *t+1* is dispatched before window *t*'s host
        post-processing, overlapping router/decode work with device compute.
        ``pipeline=False`` keeps the serial PR 4 loop (the parity reference).
        """
        if not self.pipeline:
            return self._run_serial(n_ticks)
        remaining = n_ticks
        # close a partially-filled window (tick() interleavings) exactly
        # like the serial path before entering the pipeline at a boundary
        while 0 < remaining and self._pending and self._any_active():
            w = min(self.sync_every - self._pending, remaining)
            self._dispatch_window(w)
            remaining -= w
            if self._pending >= self.sync_every:
                self.drain()
        if self._pending:
            self.drain()

        inflight = 0  # virtual ticks of the window currently on the device
        while remaining or inflight:
            if not inflight:
                # window boundary, nothing in flight: the tiered-memory
                # control plane runs here (idle-tick demotions + ready wake
                # commits; a fully idle engine blocks on its prefetch
                # tickets so a wake-only run still makes progress)
                self._boundary_ops(wait=not self._any_active())
                if not self._any_active():
                    self.stats["ticks"] += remaining
                    return
                inflight = self._next_window(remaining)
                self._dispatch_window(inflight)
                self._prefetch_rings()
                remaining -= inflight
                continue
            rings, nwin = self._fetch_rings(), inflight
            inflight = 0
            # ready wakes commit between the ring fetch and the next
            # dispatch: the prefetched buffers are already on device, so the
            # scatter joins window t+1 without flushing the pipeline. (No
            # demotions here — window t's host mirrors are still stale.)
            self._commit_ready_wakes(mark_fresh=True)
            if remaining and self._any_active() and self._gate(rings, nwin):
                # overlap: the device starts window t+1 while the host does
                # window t's decoding/router work (guaranteed control-free);
                # the window policy must see window t's still-unprocessed
                # ring tokens or its budget caps run one window stale
                inflight = self._next_window(remaining, pending=(rings, nwin))
                self._dispatch_window(inflight)
                self._prefetch_rings()
                remaining -= inflight
                self._postprocess(rings, nwin, overlapped=True)
                self.stats["overlapped_drains"] += 1
            else:
                self._postprocess(rings, nwin)
        self._boundary_ops()

    def _run_serial(self, n_ticks: int):
        """The PR 4 lockstep loop: dispatch → drain → dispatch, pinned
        ``sync_every`` windows. Kept as the bitwise parity reference."""
        remaining = n_ticks
        while remaining > 0:
            if self._pending == 0:
                self._boundary_ops(wait=not self._any_active())
            if not self._any_active():
                self.stats["ticks"] += remaining
                break
            w = min(self.sync_every - self._pending, remaining)
            if w <= 1:
                self.tick()  # drains itself when the window closes
                remaining -= 1
                continue
            self._dispatch_window(w)
            remaining -= w
            if self._pending >= self.sync_every:
                self.drain()
        self.drain()
        self._boundary_ops()

    # ------------------------------------------------------------------
    def drain(self):
        """Flush the device token rings to the host (ONE blocking transfer),
        update agent views, and run the router/spawn/merge control plane."""
        n = self._pending
        if n == 0:
            return
        self._postprocess(self._fetch_rings(), n)

    def _prefetch_rings(self):
        """Start the device→host ring copies as soon as the in-flight
        window's compute finishes, so the ``_fetch_rings`` that follows the
        overlapped host work blocks only on the residue. Only worth issuing
        where a fetch is known to follow — the pipelined ``run`` loop; the
        single-tick path overwrites the rings before any fetch."""
        self.state.main_ring.copy_to_host_async()
        self.state.side_ring.copy_to_host_async()

    def _fetch_rings(self):
        """The pipeline's sync point: ONE blocking device→host pull of the
        token rings (host numpy copies), then reset the ring cursor so the
        next dispatch — which donates the ring buffers — starts a fresh
        window immediately."""
        rings = jax.device_get((self.state.main_ring, self.state.side_ring))
        self.stats["host_syncs"] += 1
        self._pending = 0
        zero = jnp.zeros((), jnp.int32)
        if self._rep_sharding is not None:
            # a FRESH committed replicated zero each drain: the previous one
            # was donated to the last macro dispatch, and an uncommitted
            # scalar would trip the window's transfer guard at dispatch time
            zero = jax.device_put(zero, self._rep_sharding)
        self.state = dataclasses.replace(self.state, cursor=zero)
        return rings

    def _postprocess(self, rings, n: int, *, overlapped: bool = False):
        """Window ``t``'s host-side control plane over the fetched rings:
        decode text, feed the router, complete/merge sides, spawn rivers'
        tasks. With ``overlapped=True`` the next window is already on the
        device, so any control op here would be a gate violation — asserted,
        and by the gate's conservativeness unreachable."""
        main_ring, side_ring = rings
        self.stats["drains"] += 1
        quiet = True

        # 1. rivers: append the window's tokens. Decode is INCREMENTAL
        # (ISSUE 9 bugfix): a multi-byte codepoint split across the drain
        # boundary stays buffered in the agent's decoder instead of
        # becoming U+FFFD — m.text is always a bitwise prefix of the
        # one-shot decode, and agent_text() exposes the exact final form.
        main_chunks: dict[int, str] = {}
        for m in self.mains:
            if not m.active:
                continue
            if ("main", m.lane) in self._fresh_wakes:
                continue  # woke after this window ran: not on device for it
            toks = [int(t) for t in main_ring[m.lane, :n] if t >= 0]
            chunk = self._decoder(m.agent_id).feed(toks)
            m.tokens.extend(toks)
            m.text += chunk
            m.position += len(toks)
            m.steps += len(toks)
            main_chunks[m.lane] = chunk
            if self.stream_tap is not None and toks:
                self.stream_tap(m, chunk, toks)

        # 2. streams: append, detect completion (trigger or step budget)
        finished = []
        for s in self.sides:
            if not s.active:
                continue
            if ("side", s.lane) in self._fresh_wakes:
                continue  # woke after this window ran: not on device for it
            s.steps += n
            s.position += n
            raw = [int(t) for t in side_ring[s.lane, :n] if t >= 0]
            allowed = max(0, self.side_max_steps - (len(s.tokens) - s.prompt_len))
            raw = raw[:allowed]
            s.tokens.extend(raw)
            # incremental decode (ISSUE 9 bugfix): same contract as the
            # rivers — a codepoint split across windows never corrupts
            # s.text or the thought handed to the merge gate
            chunk = self._decoder(s.agent_id).feed(raw)
            s.text += chunk
            if self.stream_tap is not None and raw:
                self.stream_tap(s, chunk, raw)
            all_trig = self.router.feed(s.agent_id, chunk)
            quiet = quiet and not all_trig
            trig = [t for t in all_trig if t.kind in ("done", "answer")]
            generated = len(s.tokens) - s.prompt_len
            if trig or generated >= self.side_max_steps:
                # end of this stream: flush the decoder so s.text equals
                # the one-shot decode bitwise (an incomplete trailing
                # codepoint replaces, exactly as decode(tokens) would)
                s.text += self._decoder(s.agent_id).flush()
                answer = next((t.payload for t in trig if t.kind == "answer"), None)
                if answer is not None:
                    thought = answer
                elif trig:
                    # feed() spans are absolute offsets into the generated
                    # stream (== s.text): cut the free-running tokens the
                    # lane produced between the trigger and this drain
                    thought = s.text[: trig[0].span[1]]
                else:
                    thought = s.text
                finished.append((s, thought))

        # 3. merges (free lanes before new spawns claim them)
        assert not (overlapped and finished), "pipeline gate violated: merge"
        for s, thought in finished:
            self._merge_side(s, thought)
        quiet = quiet and not finished

        # 4. river triggers spawn new streams
        for m in self.mains:
            if not m.active or m.lane not in main_chunks:
                continue
            for tr in self.router.feed(m.agent_id, main_chunks[m.lane]):
                quiet = False
                assert not overlapped, "pipeline gate violated: trigger"
                if tr.kind == "task":
                    self._spawn_side(m, tr.payload)

        # 5. window policy: quiet drains earn longer windows, any control
        # event snaps back to the base window
        if quiet:
            self.window.on_quiet_drain()
        else:
            self.window.on_event()
        self._fresh_wakes.clear()  # next window has the woken lanes on device

    # ------------------------------------------------------------------
    def _free_side_lane(self) -> int:
        for s in self.sides:
            if not s.active:
                return s.lane
        return -1

    def _spawn_side(self, parent: AgentView, task: str, sampling: SamplingParams | None = None):
        lane = self._free_side_lane()
        if lane < 0:
            return None  # admission policy: drop when streams are saturated
        new_side_caches = self._jit_spawn(
            self.state.main_caches, self.state.side_caches, parent.lane, lane
        )
        # keep the HEAD on overflow and close the frame: the '[TASK: ... ]'
        # framing is what conditions the stream; an over-long task loses its
        # tail, never its framing
        ids = self.tok.encode(f"[TASK: {task}]")
        truncated = len(ids) > self.side_prompt_cap
        if truncated:
            close = self.tok.encode("]")
            ids = ids[: self.side_prompt_cap - len(close)] + close
        padded = ids + [0] * (self.side_prompt_cap - len(ids))
        self._side_sp[lane] = sampling if sampling is not None else self.side_sampling
        temp, tk, tp = lane_values(self._side_sp[lane])
        prompt_a, plen_a, step_a, tok_a, pos_a, act_a, samp_a = self._jit_admit_side(
            self.state.side_prompt, self.state.side_plen, self.state.side_step,
            self.state.side_tok, self.state.side_pos, self.state.side_active,
            self.state.side_samp,
            lane, jnp.asarray(padded, jnp.int32), len(ids), 0, ids[-1], parent.position,
            temp, tk, tp,
        )
        self.state = dataclasses.replace(
            self.state, side_caches=new_side_caches, side_prompt=prompt_a,
            side_plen=plen_a, side_step=step_a, side_tok=tok_a,
            side_pos=pos_a, side_active=act_a, side_samp=samp_a,
        )
        self.stats["aux_dispatches"] += 2
        s = self.sides[lane]
        if s.agent_id in self.registry and self.registry.get(s.agent_id).status != REGISTERED:
            # the classic per-lane identity is still alive (hibernated, or
            # woken into another lane): mint a fresh one for this spawn
            s = AgentView(f"side{lane}.{self._agent_seq}", lane, "side")
            self._agent_seq += 1
            self.sides[lane] = s
        s.task, s.text = task, ""
        self._decoders[s.agent_id] = self.tok.stream_decoder()
        s.parent_lane = parent.lane
        s.tokens = list(ids)
        s.position = parent.position  # continues the stream's positional frame
        s.active, s.steps = True, 0
        s.prompt_len = len(ids)
        self.prism.acquire(s.agent_id)
        self.registry.register(s.agent_id, "side")
        rec = self.registry.bind(s.agent_id, lane)
        rec.bound_tick = self.stats["ticks"]
        self.history.append(
            {"event": "spawn", "agent": s.agent_id, "task": task, "task_truncated": truncated}
        )
        return s

    # ------------------------------------------------------------------
    def retire_side(self, lane: int):
        """Cancel a stream without merging its thought (drops the lane at the
        next window boundary; its caches are rewritten on the next spawn)."""
        s = self.sides[lane]
        if not s.active:
            return
        self.drain()
        self.window.on_event()  # composition change: back to the base window
        act_a = self._jit_retire_side(self.state.side_active, lane)
        self.state = dataclasses.replace(self.state, side_active=act_a)
        self.stats["aux_dispatches"] += 1
        self.router.reset(s.agent_id)
        self.prism.release(s.agent_id)
        self.registry.release(s.agent_id)
        self._decoders.pop(s.agent_id, None)
        s.active = False
        self.history.append({"event": "retire", "agent": s.agent_id})

    def retire_main(self, lane: int):
        """Retire a river lane without replacing it (ISSUE 9: the serving
        front-end completes a request by freeing its lane for the next
        admission). Boundary op — drains first; refuses while side streams
        still target the lane for their merge (same identity-corruption
        hazard :meth:`hibernate` guards against)."""
        m = self.mains[lane]
        if not m.active:
            return
        if lane in self._lanes_with_children():
            raise ValueError(
                f"cannot retire main lane {lane}: side streams still "
                f"target it for their merge"
            )
        self.drain()
        self.window.on_event()  # composition change: back to the base window
        act_a = self._jit_retire_main(self.state.main_active, lane)
        self.state = dataclasses.replace(self.state, main_active=act_a)
        self.stats["aux_dispatches"] += 1
        m.text += self._decoder(m.agent_id).flush()  # final text == decode(tokens)
        self.router.reset(m.agent_id)
        self.prism.release(m.agent_id)
        self.registry.release(m.agent_id)
        self._decoders.pop(m.agent_id, None)
        m.active = False
        self.history.append({"event": "retire", "agent": m.agent_id})

    # ------------------------------------------------------------------
    # tiered memory (ISSUE 7): hibernate parks an agent's lane in the
    # SynapseStore (device → warm host RAM → cold zstd disk); wake prefetches
    # it back asynchronously and commits at a window boundary in run().
    # ------------------------------------------------------------------
    def _free_main_lane(self) -> int:
        for m in self.mains:
            if not m.active:
                return m.lane
        return -1

    def _lanes_with_children(self) -> set[int]:
        """Main lanes some side stream (live OR hibernated) will merge into.
        Hibernating such a main would let another agent claim the lane and
        receive the child's injection — identity corruption, so forbidden."""
        lanes = {s.parent_lane for s in self.sides if s.active}
        for rec in self.registry.with_status(HIBERNATED, "side"):
            lanes.add(rec.saved["view"].parent_lane)
        return lanes

    def _evict_lru_main(self) -> str | None:
        blocked = self._lanes_with_children()
        cands = [
            r for r in self.registry.with_status(ACTIVE, "main")
            if r.lane not in blocked
        ]
        if not cands:
            return None
        rec = min(cands, key=lambda r: r.last_event)
        self.hibernate(rec.agent_id)
        return rec.agent_id

    def hibernate(self, agent_id: str):
        """Demote an agent's lane off the device: gather its cache slice +
        per-lane scalars (ONE explicit host sync, at a drain boundary —
        never mid-window), park them in the store's warm tier, and free the
        lane. The router's retained tail for the agent survives on the host,
        so a tag split across hibernation still matches after wake."""
        rec = self.registry.get(agent_id)
        if rec.status != ACTIVE:
            raise ValueError(f"agent {agent_id!r} is not active (status={rec.status})")
        lane, kind = rec.lane, rec.kind
        view = (self.mains if kind == "main" else self.sides)[lane]
        assert view.agent_id == agent_id
        if kind == "main" and lane in self._lanes_with_children():
            raise ValueError(
                f"cannot hibernate {agent_id!r}: side streams still target "
                f"main lane {lane} for their merge"
            )
        self.drain()  # boundary-align: no mid-window host syncs
        self.window.on_event()
        if kind == "main":
            snap = self._jit_gather_main(self.state, lane)
            act_a = self._jit_retire_main(self.state.main_active, lane)
            self.state = dataclasses.replace(self.state, main_active=act_a)
            sp = self._main_sp[lane]
            self.mains[lane] = AgentView(f"main{lane}", lane, "main")
        else:
            snap = self._jit_gather_side(self.state, lane)
            act_a = self._jit_retire_side(self.state.side_active, lane)
            self.state = dataclasses.replace(self.state, side_active=act_a)
            sp = self._side_sp[lane]
            self.sides[lane] = AgentView(f"side{lane}", lane, "side")
        # durable bookkeeping rides the snapshot into the store (and, on
        # demotion, into the cold blob's frame metadata): everything needed
        # to re-adopt this agent after a process crash — the host-side view,
        # sampling params, and the router's retained tag tail
        meta = {
            "kind": kind,
            "view": _view_to_meta(view),
            "sampling": dataclasses.asdict(sp),
            "router": self.router.export_state(agent_id),
            "hibernate_tick": self.stats["ticks"],
            # a codepoint may be split across the hibernation boundary: the
            # decoder's buffered bytes ride the snapshot so the text stream
            # resumes bitwise even across a process crash (ISSUE 9)
            "utf8_pending": list(self._decoder(agent_id).pending),
        }
        self.store.put(agent_id, snap, meta=meta)  # device_get inside: the one sync
        self.stats["aux_dispatches"] += 2
        self.stats["host_syncs"] += 1
        self.stats["hibernates"] += 1
        view.active, view.lane = False, -1
        self.registry.hibernate(agent_id, {"view": view, "sampling": sp})
        self.prism.release(agent_id)
        self.history.append({"event": "hibernate", "agent": agent_id, "kind": kind})

    def wake(self, agent_id: str, *, wait: bool = False,
             deadline_s: float | None = None):
        """Promote a hibernated agent back toward a lane. Returns
        immediately after starting the async prefetch (a daemon thread pulls
        warm/cold bytes and lands them on device); the wake *commits* — the
        scatter into a free lane — at the next window boundary inside
        :meth:`run`, overlapping the in-flight window instead of flushing
        the pipeline. ``wait=True`` blocks until the agent is live.

        Failure semantics (ISSUE 8): transient prefetch failures retry with
        backoff inside the store; ``deadline_s`` (default: the engine's
        ``wake_deadline_s``) bounds the whole promotion. A wake that fails
        with the snapshot intact leaves the agent HIBERNATED (re-wakeable,
        counted in ``stats['wake_failures']``); permanent snapshot loss
        marks it LOST, frees no lane, and the engine keeps ticking."""
        rec = self.registry.get(agent_id)
        if rec.status == ACTIVE:
            return (self.mains if rec.kind == "main" else self.sides)[rec.lane]
        if rec.status != HIBERNATED:
            raise ValueError(
                f"agent {agent_id!r} has no hibernated context "
                f"(status={rec.status})"
            )
        if agent_id not in self._wake_tickets:
            sharding = self._rep_sharding

            def put_fn(host, _s=sharding):
                # runs on the prefetch thread; transfer_guard is thread-local
                # so these explicit copies never trip the engine's guard
                return jax.device_put(host, _s) if _s is not None else jax.device_put(host)

            self._wake_tickets[agent_id] = self.store.prefetch(
                agent_id, put_fn,
                deadline_s=self.wake_deadline_s if deadline_s is None else deadline_s,
            )
            self._pending_wakes.append(agent_id)
        if wait:
            self.flush_wakes()
            rec = self.registry.get(agent_id)
            if rec.status != ACTIVE:
                if rec.status == LOST:
                    raise SnapshotLostError(
                        agent_id, "context permanently lost during wake"
                    )
                raise RuntimeError(
                    f"wake of {agent_id!r} did not land "
                    f"(status={rec.status}: lane-starved or wake failed)"
                )
            return (self.mains if rec.kind == "main" else self.sides)[rec.lane]
        return rec

    def flush_wakes(self):
        """Block until every pending wake has committed (or is lane-starved)."""
        self.drain()
        self._commit_ready_wakes(wait=True)

    def _commit_ready_wakes(self, *, wait: bool = False, mark_fresh: bool = False) -> int:
        """Land prefetched wakes whose device buffers are ready. Callers
        guarantee a window boundary (ring cursor 0, no partial window): the
        scatter dispatches here are boundary ops, outside any overlap
        region, so the zero-transfer invariant of overlapped post-processing
        is untouched."""
        if not self._pending_wakes:
            return 0
        assert self._pending == 0, "wake commit must happen at a window boundary"
        # supervision: a dead prefetch thread is detected here (its in-flight
        # ticket fails instead of hanging a waiter) and respawned for the
        # still-queued tickets
        self.store.heal_worker()
        committed, still = 0, []
        for aid in self._pending_wakes:
            ticket = self._wake_tickets[aid]
            ticket.expire()  # host-side deadline: a stuck worker can't block this
            if not ticket.failed() and not (wait or ticket.ready()):
                still.append(aid)
                continue
            if wait and not ticket.ready():
                try:
                    ticket.result(timeout=ticket.remaining())
                except Exception:
                    pass  # terminal state is recorded on the ticket itself
                ticket.expire()
            if ticket.failed():
                self._fail_wake(aid, ticket.error)
                continue  # degraded, not pending: engine keeps ticking
            if self._commit_wake(aid, ticket, mark_fresh=mark_fresh):
                committed += 1
            else:
                still.append(aid)  # lane-starved: stays pending
        self._pending_wakes = still
        return committed

    def _fail_wake(self, agent_id: str, err: BaseException | None) -> None:
        """A wake ticket reached the terminal failed state. Degrade, never
        crash: a KeyError-family failure (quarantined blob, vanished file,
        dropped snapshot) means the context is unrecoverable — mark the
        agent LOST and move on; anything else (deadline, dead worker,
        exhausted transient retries) leaves the snapshot intact, so the
        agent stays HIBERNATED and a later wake() may succeed."""
        self._wake_tickets.pop(agent_id, None)
        if isinstance(err, KeyError) or agent_id not in self.store:
            self.registry.mark_lost(agent_id)
            self.store.drop(agent_id)
            self.router.reset(agent_id)
            self._decoders.pop(agent_id, None)
            self.stats["lost_agents"] += 1
            self.history.append(
                {"event": "lost", "agent": agent_id, "error": repr(err)}
            )
        else:
            self.stats["wake_failures"] += 1
            self.history.append(
                {"event": "wake_failed", "agent": agent_id, "error": repr(err)}
            )

    def _commit_wake(self, agent_id: str, ticket, *, mark_fresh: bool = False) -> bool:
        rec = self.registry.get(agent_id)
        kind = rec.kind
        lane = self._free_main_lane() if kind == "main" else self._free_side_lane()
        if lane < 0:
            return False
        part = ticket.result()  # device pytree (prefetch thread did the put)
        del self._wake_tickets[agent_id]
        saved = rec.saved
        view, sp = saved["view"], saved["sampling"]
        temp, tk, tp = lane_values(sp)
        if kind == "main":
            self._main_sp[lane] = sp
            caches = self._jit_wake_main_caches(self.state.main_caches, part["caches"], lane)
            tok_a, pos_a, act_a, hid_a, samp_a = self._jit_admit_main(
                self.state.main_tok, self.state.main_pos, self.state.main_active,
                self.state.main_hidden, self.state.main_samp,
                lane, part["tok"], part["pos"], part["hidden"], temp, tk, tp,
            )
            self.state = dataclasses.replace(
                self.state, main_caches=caches, main_tok=tok_a, main_pos=pos_a,
                main_active=act_a, main_hidden=hid_a, main_samp=samp_a,
            )
            self.mains[lane] = view
        else:
            self._side_sp[lane] = sp
            caches = self._jit_wake_side_caches(self.state.side_caches, part["caches"], lane)
            prompt_a, plen_a, step_a, tok_a, pos_a, act_a, samp_a = self._jit_admit_side(
                self.state.side_prompt, self.state.side_plen, self.state.side_step,
                self.state.side_tok, self.state.side_pos, self.state.side_active,
                self.state.side_samp,
                lane, part["prompt"], part["plen"], part["step"], part["tok"],
                part["pos"], temp, tk, tp,
            )
            hid_a = self._jit_set_side_hidden(self.state.side_hidden, lane, part["hidden"])
            self.state = dataclasses.replace(
                self.state, side_caches=caches, side_prompt=prompt_a,
                side_plen=plen_a, side_step=step_a, side_tok=tok_a,
                side_pos=pos_a, side_active=act_a, side_samp=samp_a,
                side_hidden=hid_a,
            )
            self.sides[lane] = view
        view.lane, view.active = lane, True
        self.stats["aux_dispatches"] += 2 if kind == "main" else 3
        self.stats["wakes"] += 1
        self.prism.acquire(agent_id)
        bound = self.registry.bind(agent_id, lane)
        bound.bound_tick = self.stats["ticks"]
        self.store.drop(agent_id)
        self.window.on_event()
        if mark_fresh:
            # a fetched-but-unprocessed window exists: this lane was not on
            # device for it, so its mirror advancement must be skipped once
            self._fresh_wakes.add((kind, lane))
        self.history.append({"event": "wake", "agent": agent_id, "lane": lane})
        return True

    def adopt_hibernated(self, *, kinds=("main", "side")) -> list[str]:
        """Crash-recovery re-adoption (ISSUE 8): after ``store.recover()``
        rebuilt the cold index from disk, re-register every snapshot whose
        durable metadata names an agent this engine does not already hold,
        restoring the host-side view, sampling params, and the router's
        retained tag tail. Adopted agents come back HIBERNATED — a normal
        :meth:`wake` makes them live, and their greedy streams replay
        bitwise as if the process never died. Returns the adopted ids."""
        adopted = []
        for key in self.store.keys():
            meta = self.store.meta_of(key)
            if not isinstance(meta, dict) or meta.get("kind") not in kinds:
                continue
            if key in self.registry and self.registry.get(key).status in (
                ACTIVE, HIBERNATED,
            ):
                continue  # a live identity wins over its stale snapshot
            view = _view_from_meta(meta["view"])
            sp = SamplingParams(**meta["sampling"])
            self.registry.register(key, meta["kind"])
            self.registry.hibernate(key, {"view": view, "sampling": sp})
            if meta.get("router"):
                self.router.restore_state(key, meta["router"])
            if meta.get("utf8_pending"):
                # resume mid-codepoint: the decoder picks the byte stream
                # back up exactly where the dead process left it
                self._decoder(key).restore(bytes(meta["utf8_pending"]))
            self.stats["recoveries"] += 1
            self.history.append({"event": "adopt", "agent": key})
            adopted.append(key)
        return adopted

    def _auto_hibernate(self) -> int:
        """Idle-ticks demotion policy: mains whose last control event
        (submit/wake) is more than ``hibernate_idle_ticks`` virtual ticks
        old spill to the warm tier. Runs only at fully-synced boundaries
        (views current, nothing in flight)."""
        if self.hibernate_idle_ticks is None:
            return 0
        blocked = self._lanes_with_children()
        due = [
            r for r in self.registry.with_status(ACTIVE, "main")
            if self.stats["ticks"] - r.bound_tick >= self.hibernate_idle_ticks
            and r.lane not in blocked
        ]
        for r in due:
            self.hibernate(r.agent_id)
        return len(due)

    def _boundary_ops(self, *, wait: bool = False, hibernate_ok: bool = True) -> int:
        """Window-boundary control plane: idle-ticks demotions, then wake
        commits. ``wait=True`` blocks on outstanding prefetch tickets — used
        when the engine is otherwise idle so a wake-only run makes progress."""
        did = 0
        if hibernate_ok:
            did += self._auto_hibernate()
        did += self._commit_ready_wakes(wait=wait and bool(self._pending_wakes))
        if self.admission_hook is not None:
            # front-end admission control (ISSUE 9): retire finished
            # request lanes and admit queued work — all boundary ops, so
            # the pipelined window is never flushed by an admission
            did += bool(self.admission_hook())
        return did

    # ------------------------------------------------------------------
    def _merge_side(self, s: AgentView, thought: str):
        ids = self.tok.encode(thought)[-self.inject_tokens:]
        ids = ids + [self.tok.pad_id] * (self.inject_tokens - len(ids))
        toks = jnp.tile(jnp.asarray(ids, jnp.int32)[None], (self.n_main, 1))
        vpos = jnp.asarray([m.position for m in self.mains], jnp.int32)  # virtual index
        lane_mask = jnp.arange(self.n_main) == s.parent_lane
        new_caches, accept, score = self._jit_merge(
            self._params, self.state.main_caches, self.state.main_hidden,
            toks, vpos, lane_mask,
        )
        act_a = self._jit_retire_side(self.state.side_active, s.lane)
        self.state = dataclasses.replace(
            self.state, main_caches=new_caches, side_active=act_a
        )
        self.stats["aux_dispatches"] += 2
        accepted = bool(np.asarray(accept)[s.parent_lane])  # drain-time sync
        self.stats["host_syncs"] += 1
        self.history.append(
            {
                "event": "merge",
                "agent": s.agent_id,
                "accepted": accepted,
                "gate_score": float(np.asarray(score)[s.parent_lane]),
                "thought": thought[:80],
            }
        )
        self.router.reset(s.agent_id)
        self.prism.release(s.agent_id)
        self.registry.release(s.agent_id)
        self._decoders.pop(s.agent_id, None)
        s.active = False

    # ------------------------------------------------------------------
    def memory_report(self) -> dict:
        self.drain()  # lazy flush: reporting is a natural sync boundary
        per_agent = {}
        for m in self.mains:
            if m.active:
                per_agent[m.agent_id] = tree_bytes(_lane_slice(self.state.main_caches, m.lane))
        for s in self.sides:
            if s.active:
                per_agent[s.agent_id] = tree_bytes(_lane_slice(self.state.side_caches, s.lane))
        # hibernated agents are absent from per_agent by construction: their
        # device contribution is exactly the zero bytes the tiers promise
        rep = self.prism.memory_report(
            per_agent,
            store_report=self.store.report(),
            agents=self.registry.counts(),
        )
        rep["per_agent_bytes"] = dict(per_agent)
        # the serving-dtype weight cast is a REAL resident copy on backends
        # where compute dtype != param dtype (identity casts alias, cost 0);
        # Eq. 1 accounting must include it
        cast_extra = sum(
            b.size * b.dtype.itemsize
            for a, b in zip(jax.tree.leaves(self.prism.params), jax.tree.leaves(self._params))
            if b is not a
        )
        rep["serving_weight_bytes"] = cast_extra
        rep["total_bytes"] += cast_extra
        return rep
