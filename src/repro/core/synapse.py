"""The Topological Synapse (paper §3.3) — KV-cache landmark sparsification.

Two modes:

1. ``compress`` (paper-faithful): one-shot hybrid density-coverage landmark
   selection from a full cache, used when spawning a side agent. The hybrid
   score is
       score_i = alpha * density_i + (1 - alpha) * coverage_i
   where density_i is the paper's "Attention Score Summation" (softmax
   attention mass of the main agent's current query over key i, summed over
   heads — an inverse kernel-density estimate on the KV point cloud) and
   coverage_i is the greedy maxmin (farthest-point) term that bounds the
   Hausdorff distance of the landmark set to the context manifold. This is
   exactly the hybrid landmarking of [Ruiz Williams 2025] ported to the
   transformer latent space.

2. ``synapse_decode`` (streaming extension, beyond-paper): the same policy
   run online during decode — a recent-window ring plus a landmark buffer
   with hybrid-score eviction. This makes dense-architecture decode O(K+W+J)
   per step and is what unlocks the long_500k shape (DESIGN.md §4).

Both operate per layer, vectorized over the batch/agent axis.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import synapse_sharded as sharded
from repro.models import cache as cache_lib
from repro.models.attention import decode_attend, _project_qkv, _rotate
from repro.models.config import ModelConfig

NEG_INF = -1e30


@dataclass(frozen=True)
class SynapsePolicy:
    alpha: float = 0.5        # density vs coverage blend
    score_ema: float = 0.99   # per-step decay of accumulated attention mass
    coverage_cap: float = 4.0 # maxmin distances saturate here (normalized units)
    # decode attend implementation: "pallas" = fused kernels.ops.synapse_attention
    # over the concatenated [landmarks; window; inject] set (single device,
    # interpret mode on CPU); "piece" = synapse_sharded.piece_attend (the
    # multi-chip flash-decode). A live shard axis always forces "piece".
    attend_impl: str = "pallas"
    # mesh axis the synapse token dims are sharded over (None = local). The
    # engine-owned replacement for the old synapse_sharded.set_shard_axis
    # module global: the policy rides the CacheSpec through decode_step into
    # kernels.ops.synapse_attend, so shard placement is scoped to the trace
    # that owns it. (The engine's LANE sharding keeps this None — lanes are
    # split across devices, each lane's token dims stay local.)
    shard_axis: str | None = None


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------
def _pool_heads(k):
    """[..., Hkv, D] -> [..., D] mean over kv heads (coverage geometry)."""
    return k.astype(jnp.float32).mean(axis=-2)


def _normed_dist(a, b):
    """||a-b|| / sqrt(d): a [..., T, D], b [..., D] -> [..., T]."""
    d = a.shape[-1]
    diff = a - b[..., None, :]
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1) / d)


def attention_density(q, keys, valid):
    """Paper Eq. in §3.3: softmax attention mass per key, summed over heads.

    q: [B, H, D]; keys: [B, T, Hkv, D]; valid: [B, T] -> [B, T] f32.
    """
    _, mass = decode_attend(q, keys, jnp.zeros_like(keys), valid)
    return mass


def kernel_density(q, keys, valid):
    """attention_density via kernels.ops.landmark_score: one fused sweep over
    the cache computes the per-head logits (the bandwidth-bound half); the
    valid-masked softmax normalization is a cheap [B,H,T] reduction. Falls
    back to the jnp path when a shard axis is live (Pallas blocks are not
    GSPMD-partitionable)."""
    from repro.kernels import ops  # deferred: kernels are optional at import

    if sharded.get_shard_axis() is not None:
        return attention_density(q, keys, valid)
    density, _ = ops.landmark_score(q, keys, None, valid)  # density-only sweep
    return density


def _attend(q1, pieces, valids, scale, policy: SynapsePolicy):
    """Attend over [landmarks; window; inject] k/v pieces — delegates to
    :func:`repro.kernels.ops.synapse_attend`, which routes on the policy
    (fused Pallas attend vs the token-sharded flash-decode piece_attend).
    Returns (out [B,H,D], masses — one [B,T_i] per piece)."""
    from repro.kernels import ops

    return ops.synapse_attend(q1, pieces, valids, scale=scale, policy=policy)


# ---------------------------------------------------------------------------
# one-shot compression (paper-faithful side-agent spawn)
# ---------------------------------------------------------------------------
def select_landmarks(keys, valid, density, k: int, policy: SynapsePolicy):
    """Greedy hybrid density-coverage selection.

    keys: [B, T, Hkv, D]; valid: [B, T]; density: [B, T].
    Returns indices [B, k] (sorted by position) and the hybrid scores [B, k].
    """
    B, T = density.shape
    pooled = _pool_heads(keys)  # [B, T, D]
    density = density / (jnp.max(density, axis=-1, keepdims=True) + 1e-9)
    cap = policy.coverage_cap

    def body(i, carry):
        min_dist, chosen_idx, chosen_score, taken = carry
        cov = jnp.minimum(min_dist, cap) / cap
        score = policy.alpha * density + (1.0 - policy.alpha) * cov
        score = jnp.where(valid & ~taken, score, NEG_INF)
        idx = jnp.argmax(score, axis=-1)  # [B]
        best = jnp.take_along_axis(score, idx[:, None], axis=-1)[:, 0]
        new_lm = jnp.take_along_axis(pooled, idx[:, None, None], axis=1)[:, 0]  # [B, D]
        min_dist = jnp.minimum(min_dist, _normed_dist(pooled, new_lm))
        taken = taken | (jax.nn.one_hot(idx, T, dtype=bool))
        chosen_idx = chosen_idx.at[:, i].set(idx)
        chosen_score = chosen_score.at[:, i].set(best)
        return min_dist, chosen_idx, chosen_score, taken

    init = (
        jnp.full((B, T), jnp.inf, jnp.float32),
        jnp.zeros((B, k), jnp.int32),
        jnp.zeros((B, k), jnp.float32),
        jnp.zeros((B, T), bool),
    )
    _, idx, score, _ = jax.lax.fori_loop(0, k, body, init)
    picked_valid = score > NEG_INF / 2  # False when T_valid < k (short prompts)
    return idx, score, picked_valid


def compress(
    cfg: ModelConfig,
    cache: cache_lib.FullCache,
    query,  # [B, H, D] — the main agent's current query state (paper: Q_t), or
            # None to use the cache's accumulated attention-mass density
    n_landmarks: int,
    window: int,
    n_inject: int = 0,
    policy: SynapsePolicy = SynapsePolicy(),
) -> cache_lib.SynapseCache:
    """Full cache -> SynapseCache for a freshly spawned side agent."""
    B, T = cache.pos.shape
    slots = jnp.arange(T)
    valid = slots[None, :] < cache.length[:, None]
    density = kernel_density(query, cache.k, valid) if query is not None else cache.score
    idx, score, picked = select_landmarks(cache.k, valid, density, n_landmarks, policy)
    # stable order: sort landmarks by original position; invalid picks last
    pos_sel = jnp.take_along_axis(cache.pos, idx, axis=1)
    pos_sel = jnp.where(picked, pos_sel, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(pos_sel, axis=1)
    idx = jnp.take_along_axis(idx, order, axis=1)
    score = jnp.take_along_axis(score, order, axis=1)

    gather = lambda a: jnp.take_along_axis(a, idx[:, :, None, None], axis=1)
    syn = cache_lib.init_synapse_cache(
        cfg, B, n_landmarks, window, n_inject, dtype=cache.k.dtype
    )
    k_valid = jnp.minimum(cache.length, n_landmarks)
    return cache_lib.SynapseCache(
        lm_k=gather(cache.k),
        lm_v=gather(cache.v),
        lm_pos=jnp.take_along_axis(cache.pos, idx, axis=1),
        lm_score=score,
        lm_count=k_valid,
        win_k=syn.win_k,
        win_v=syn.win_v,
        win_pos=syn.win_pos,
        win_score=syn.win_score,
        inj_k=syn.inj_k,
        inj_v=syn.inj_v,
        inj_pos=syn.inj_pos,
        inj_count=syn.inj_count,
        win_count=jnp.zeros_like(cache.length),
        length=cache.length,
    )


# ---------------------------------------------------------------------------
# streaming decode over a SynapseCache
# ---------------------------------------------------------------------------
def synapse_decode(
    attn_params,
    cfg: ModelConfig,
    x,          # [B, 1, dm]
    cache: cache_lib.SynapseCache,
    positions,  # [B] (or [B,3] mrope)
    policy: SynapsePolicy = SynapsePolicy(),
):
    """One decode step: attend over [landmarks; window; inject slots], write
    the new token into the window ring, graduate/evict on overflow.

    Returns (y [B,1,dm], new_cache, stats dict).
    """
    B = x.shape[0]
    K, W, J = cache.n_landmarks, cache.window, cache.n_inject
    q, k, v = _project_qkv(attn_params, cfg, x)
    if cfg.rope_kind == "mrope":
        q = _rotate(cfg, q, positions[..., None])
        k = _rotate(cfg, k, positions[..., None])
        pos_scalar = positions[:, 0]
    else:
        q = _rotate(cfg, q, positions[..., None])
        k = _rotate(cfg, k, positions[..., None])
        pos_scalar = positions
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]

    # ---- 1. graduation: the slot the new token will overwrite ----
    # one-hot reads/writes shard over the token dim without scatter
    # (EXPERIMENTS.md §Perf: SPMD 'involuntary full rematerialization').
    slot = cache.win_count % W  # [B]
    win_full = cache.win_count >= W
    grad_k = sharded.onehot_read(cache.win_k, slot)      # [B, Hkv, D]
    grad_v = sharded.onehot_read(cache.win_v, slot)
    grad_pos = sharded.onehot_read(cache.win_pos, slot)
    grad_score = sharded.onehot_read(cache.win_score, slot)

    pooled_lm = _pool_heads(cache.lm_k)                   # [B, K, D]
    grad_pooled = _pool_heads(grad_k[:, None])[:, 0]      # [B, D]
    dist = _normed_dist(pooled_lm, grad_pooled)           # [B, K]
    lm_slot_valid = jnp.arange(K)[None, :] < cache.lm_count[:, None]
    min_dist = jnp.min(jnp.where(lm_slot_valid, dist, jnp.inf), axis=-1)
    cov = jnp.minimum(jnp.where(jnp.isfinite(min_dist), min_dist, policy.coverage_cap), policy.coverage_cap) / policy.coverage_cap

    # Rate-based comparison: landmark scores are EMAs that saturate at
    # mass_rate/(1-ema) after long residency, while a graduating token only
    # accumulated for ~W steps — comparing raw totals freezes the landmark
    # set on the earliest tokens. Convert both to per-step attention-mass
    # rates; the coverage bonus is scaled into rate units by the mean
    # landmark rate so the hybrid blend stays dimensionally consistent.
    one_minus_ema = max(1.0 - policy.score_ema, 1e-6)
    resid = jnp.minimum(jnp.maximum(cache.win_count.astype(jnp.float32), 1.0), float(W))
    grad_rate = grad_score / resid
    lm_rate = cache.lm_score * one_minus_ema                      # [B, K]
    lm_rate_masked = jnp.where(lm_slot_valid, lm_rate, jnp.inf)
    min_lm_rate = jnp.min(lm_rate_masked, axis=-1)
    mean_lm_rate = jnp.sum(jnp.where(lm_slot_valid, lm_rate, 0.0), axis=-1) / jnp.maximum(
        cache.lm_count.astype(jnp.float32), 1.0
    )
    hybrid_rate = policy.alpha * grad_rate + (1 - policy.alpha) * cov * jnp.maximum(
        mean_lm_rate, grad_rate
    )

    # candidate landmark slot: first empty, else argmin rate
    evict_slot = jnp.where(
        cache.lm_count < K,
        cache.lm_count,
        jnp.argmin(jnp.where(lm_slot_valid, lm_rate, jnp.inf), axis=-1),
    )
    promote = win_full & ((cache.lm_count < K) | (hybrid_rate > min_lm_rate))

    lm_k = sharded.onehot_write(cache.lm_k, evict_slot, grad_k, mask=promote)
    lm_v = sharded.onehot_write(cache.lm_v, evict_slot, grad_v, mask=promote)
    lm_pos = sharded.onehot_write(cache.lm_pos, evict_slot, grad_pos, mask=promote)
    # store back in EMA-steady units so future comparisons stay consistent
    lm_score = sharded.onehot_write(
        cache.lm_score, evict_slot, hybrid_rate / one_minus_ema, mask=promote
    )
    lm_count = jnp.where(promote, jnp.minimum(cache.lm_count + 1, K), cache.lm_count)

    # ---- 2. write the new token into the ring ----
    win_k = sharded.onehot_write(cache.win_k, slot, k1)
    win_v = sharded.onehot_write(cache.win_v, slot, v1)
    win_pos = sharded.onehot_write(cache.win_pos, slot, pos_scalar)
    win_score = sharded.onehot_write(cache.win_score, slot, jnp.zeros((B,), jnp.float32))

    # ---- 3. attend over [landmarks; window; inject] ----
    # default: one fused Pallas pass over the concatenated token set (the
    # buffers leave HBM exactly once per step); sharded runs flash-decode
    # over token-sharded pieces, crossing chips with [B,Hkv,G] stats only.
    lm_valid = jnp.arange(K)[None, :] < lm_count[:, None]
    win_valid = jnp.arange(W)[None, :] < jnp.minimum(cache.win_count + 1, W)[:, None]
    inj_valid = jnp.arange(J)[None, :] < cache.inj_count[:, None]
    scale = 1.0 / (q1.shape[-1] ** 0.5)
    out, masses = _attend(
        q1,
        [(lm_k, lm_v), (win_k, win_v), (cache.inj_k, cache.inj_v)],
        [lm_valid, win_valid, inj_valid],
        scale,
        policy,
    )
    y = out.reshape(B, -1) @ attn_params["wo"]

    # ---- 4. accumulate attention mass (density statistic) ----
    ema = policy.score_ema
    lm_score = lm_score * ema + masses[0]
    win_score = win_score * ema + masses[1]
    mass = jnp.concatenate(masses, axis=1)

    new_cache = cache_lib.SynapseCache(
        lm_k=lm_k, lm_v=lm_v, lm_pos=lm_pos, lm_score=lm_score, lm_count=lm_count,
        win_k=win_k, win_v=win_v, win_pos=win_pos, win_score=win_score,
        inj_k=cache.inj_k, inj_v=cache.inj_v, inj_pos=cache.inj_pos,
        inj_count=cache.inj_count, win_count=cache.win_count + 1,
        length=cache.length + 1,
    )
    stats = {"promoted": promote, "attn_mass_landmarks": mass[:, :K].sum(-1)}
    return y[:, None, :], new_cache, stats


def synapse_bytes(cfg: ModelConfig, n_landmarks: int, window: int, n_inject: int, n_layers: int | None = None) -> int:
    """Per-agent synapse footprint (the paper's ~10 MB claim)."""
    syn = cache_lib.init_synapse_cache(cfg, 1, n_landmarks, window, n_inject)
    per_layer = cache_lib.cache_bytes(syn)
    return per_layer * (n_layers if n_layers is not None else cfg.n_layers)
