"""Sharding-aware primitives for the streaming synapse decode (§Perf).

Two findings from the hillclimb drive this module (EXPERIMENTS.md §Perf,
pair qwen3-8b x long_500k):

1. GSPMD turns dynamic-index scatter/gather on token-sharded synapse buffers
   into "involuntary full rematerialization" (replicate -> scatter ->
   reshard), and the attend over the concat forces a per-step f32 all-gather
   of every buffer. One-hot select/contract formulations are elementwise
   over the token dim and shard for free.

2. Softmax over a token-sharded axis cannot be expressed by GSPMD without a
   gather; a shard_map flash-decode (local partial max/sum + psum combine)
   moves only [B,Hkv,G]-sized statistics across chips instead of the
   buffers themselves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30

# Mesh axis the synapse token dims are sharded over (set by launch entry
# points before tracing under a mesh; None = single-device / engine path).
_SHARD_AXIS = None
_MESH = None


def set_shard_axis(axis: str | None, mesh=None):
    global _SHARD_AXIS, _MESH
    _SHARD_AXIS = axis
    _MESH = mesh


def get_shard_axis():
    return _SHARD_AXIS


def onehot_write(buf, slot, new, mask=None):
    """buf [B,T,...] <- new [B,...] at per-lane `slot`, via one-hot select.

    Single-device (no shard axis — the engine hot path): a plain per-lane
    scatter, bitwise-identical to the one-hot select for in-bounds slots
    (0 <= slot < T, which every caller guarantees — the one-hot form drops
    out-of-range slots while a scatter would clamp) but without
    materializing [B,T]-shaped masks for every ring write of every layer
    of every virtual tick."""
    if _SHARD_AXIS is None:
        lane = jnp.arange(buf.shape[0])
        val = new.astype(buf.dtype)
        if mask is not None:
            cur = buf[lane, slot]
            m = mask.reshape(mask.shape + (1,) * (val.ndim - 1))
            val = jnp.where(m, val, cur)
        return buf.at[lane, slot].set(val)
    T = buf.shape[1]
    oh = jax.nn.one_hot(slot, T, dtype=bool)  # [B, T]
    if mask is not None:
        oh = oh & mask[:, None]
    oh = oh.reshape(oh.shape + (1,) * (buf.ndim - 2))
    return jnp.where(oh, new[:, None].astype(buf.dtype), buf)


def onehot_read(buf, slot):
    """buf [B,T,...] -> [B,...] at per-lane slot (one-hot contraction; plain
    per-lane gather when no shard axis is live — exact for f32/int32 and
    in-bounds slots, so the two formulations are interchangeable there)."""
    if _SHARD_AXIS is None:
        return buf[jnp.arange(buf.shape[0]), slot]
    T = buf.shape[1]
    oh = jax.nn.one_hot(slot, T, dtype=jnp.float32)
    out = jnp.einsum("bt,bt...->b...", oh, buf.astype(jnp.float32))
    return out.astype(buf.dtype)


def piece_attend(q, pieces, valids, scale):
    """Flash-decode attend over token-sharded (k, v) pieces.

    q: [B,H,D]; pieces: [(k_i, v_i)] with k_i/v_i [B,T_i,Hkv,D] sharded on
    T_i over the configured axis; valids: [(B,T_i)] bools.
    Returns (out [B,H,D], masses [(B,T_i)] — per-key probability mass).
    Falls back to a plain local computation when no shard axis is set.
    """
    axis = _SHARD_AXIS
    B, H, D = q.shape
    Hkv = pieces[0][0].shape[2]
    G = H // Hkv
    sizes = [k.shape[1] for k, _ in pieces]

    def body(q, *flat, use_psum: bool):
        n = len(pieces)
        ks, vs, ms = flat[:n], flat[n : 2 * n], flat[2 * n :]
        k_loc = jnp.concatenate(ks, axis=1)
        v_loc = jnp.concatenate(vs, axis=1)
        valid_loc = jnp.concatenate(ms, axis=1)
        qg = q.reshape(B, Hkv, G, D)
        s = jnp.einsum("bkgd,btkd->bkgt", qg, k_loc).astype(jnp.float32) * scale
        s = jnp.where(valid_loc[:, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)
        m = jax.lax.pmax(m_loc, axis) if use_psum else m_loc
        e = jnp.exp(s - m[..., None])
        denom = jnp.sum(e, axis=-1)
        if use_psum:
            denom = jax.lax.psum(denom, axis)
        p = e / denom[..., None]
        out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_loc.dtype), v_loc)
        if use_psum:
            out = jax.lax.psum(out, axis)
        mass_loc = p.sum(axis=(1, 2))
        local_sizes = [k.shape[1] for k in ks]
        splits = list(np.cumsum(local_sizes))[:-1]
        masses = jnp.split(mass_loc, splits, axis=1)
        return (out.reshape(B, H, D), *masses)

    flat = [k for k, _ in pieces] + [v for _, v in pieces] + list(valids)
    if axis is None:
        res = body(q, *flat, use_psum=False)
        return res[0], list(res[1:])

    from jax.sharding import PartitionSpec as P

    tok = P(None, axis, None, None)
    tokm = P(None, axis)
    rep3 = P(None, None, None)
    in_specs = (rep3, *([tok] * len(pieces)), *([tok] * len(pieces)), *([tokm] * len(pieces)))
    out_specs = (rep3, *([tokm] * len(pieces)))
    import functools

    res = jax.shard_map(
        functools.partial(body, use_psum=True),
        mesh=_MESH,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False,
    )(q, *flat)
    return res[0], list(res[1:])
