"""Sharding-aware primitives for the streaming synapse decode (§Perf).

Two findings from the hillclimb drive this module (EXPERIMENTS.md §Perf,
pair qwen3-8b x long_500k):

1. GSPMD turns dynamic-index scatter/gather on token-sharded synapse buffers
   into "involuntary full rematerialization" (replicate -> scatter ->
   reshard), and the attend over the concat forces a per-step f32 all-gather
   of every buffer. One-hot select/contract formulations are elementwise
   over the token dim and shard for free.

2. Softmax over a token-sharded axis cannot be expressed by GSPMD without a
   gather; a shard_map flash-decode (local partial max/sum + psum combine)
   moves only [B,Hkv,G]-sized statistics across chips instead of the
   buffers themselves.

Shard placement is SCOPED, not global: callers either pass an explicit
:class:`ShardContext` (the engine threads one via its ``SynapsePolicy``) or
enter :func:`token_sharding` around tracing (the dry-run). The old
``set_shard_axis`` module global is gone — a test or launch script that set
it would leak interpreter-wide state into every later trace.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from contextvars import ContextVar

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.6: public jax.shard_map, replication check spelled check_vma
    from jax import shard_map as _shard_map

    _SM_NOCHECK = {"check_vma": False}
except ImportError:  # jax <= 0.5: experimental module, spelled check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    _SM_NOCHECK = {"check_rep": False}

NEG_INF = -1e30


def shard_map_nocheck(fn, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` with the replication check disabled
    (the engine's macro tick mixes replicated main-lane state with
    lane-sharded side state — the static checker cannot prove that)."""
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **_SM_NOCHECK)


@dataclasses.dataclass(frozen=True)
class ShardContext:
    """Token-shard placement for the synapse buffers: the mesh axis their
    token dims are split over (None = everything local) plus the mesh that
    owns the axis (required whenever ``axis`` is set)."""

    axis: str | None = None
    mesh: object | None = None


_CTX: ContextVar[ShardContext] = ContextVar(
    "synapse_shard_ctx", default=ShardContext()
)


@contextlib.contextmanager
def token_sharding(axis: str | None, mesh=None):
    """Scoped token-shard placement for code that cannot thread an explicit
    :class:`ShardContext` (e.g. the dry-run tracing a whole decode step).
    Always restores the previous context on exit, even on error — the
    leak-proof replacement for the old ``set_shard_axis`` global."""
    token = _CTX.set(ShardContext(axis, mesh))
    try:
        yield _CTX.get()
    finally:
        _CTX.reset(token)


def current_context() -> ShardContext:
    return _CTX.get()


def get_shard_axis() -> str | None:
    return _CTX.get().axis


def _resolve(ctx: ShardContext | None) -> ShardContext:
    return _CTX.get() if ctx is None else ctx


def onehot_write(buf, slot, new, mask=None, *, ctx: ShardContext | None = None):
    """buf [B,T,...] <- new [B,...] at per-lane `slot`, via one-hot select.

    Single-device (no shard axis — the engine hot path): a plain per-lane
    scatter, bitwise-identical to the one-hot select for in-bounds slots
    (0 <= slot < T, which every caller guarantees — the one-hot form drops
    out-of-range slots while a scatter would clamp) but without
    materializing [B,T]-shaped masks for every ring write of every layer
    of every virtual tick."""
    if _resolve(ctx).axis is None:
        lane = jnp.arange(buf.shape[0])
        val = new.astype(buf.dtype)
        if mask is not None:
            cur = buf[lane, slot]
            m = mask.reshape(mask.shape + (1,) * (val.ndim - 1))
            val = jnp.where(m, val, cur)
        return buf.at[lane, slot].set(val)
    T = buf.shape[1]
    oh = jax.nn.one_hot(slot, T, dtype=bool)  # [B, T]
    if mask is not None:
        oh = oh & mask[:, None]
    oh = oh.reshape(oh.shape + (1,) * (buf.ndim - 2))
    return jnp.where(oh, new[:, None].astype(buf.dtype), buf)


def onehot_read(buf, slot, *, ctx: ShardContext | None = None):
    """buf [B,T,...] -> [B,...] at per-lane slot (one-hot contraction; plain
    per-lane gather when no shard axis is live — exact for f32/int32 and
    in-bounds slots, so the two formulations are interchangeable there)."""
    if _resolve(ctx).axis is None:
        return buf[jnp.arange(buf.shape[0]), slot]
    T = buf.shape[1]
    oh = jax.nn.one_hot(slot, T, dtype=jnp.float32)
    out = jnp.einsum("bt,bt...->b...", oh, buf.astype(jnp.float32))
    return out.astype(buf.dtype)


def piece_attend(q, pieces, valids, scale, *, ctx: ShardContext | None = None):
    """Flash-decode attend over token-sharded (k, v) pieces.

    q: [B,H,D]; pieces: [(k_i, v_i)] with k_i/v_i [B,T_i,Hkv,D] sharded on
    T_i over ``ctx.axis``; valids: [(B,T_i)] bools.
    Returns (out [B,H,D], masses [(B,T_i)] — per-key probability mass).

    No shard axis (the lane-sharded engine's per-shard body, and the
    single-device fallback): ONE fused ``kernels.ops.synapse_attention``
    call over the concatenated set — the exact computation of the default
    "pallas" attend, so lane-sharded and single-device engines stay BITWISE
    identical (tests/test_lane_sharded.py pins this).
    """
    axis = _resolve(ctx).axis
    B, H, D = q.shape
    Hkv = pieces[0][0].shape[2]
    G = H // Hkv
    sizes = [k.shape[1] for k, _ in pieces]

    if axis is None:
        from repro.kernels import ops  # deferred: keeps core importable alone

        k_all = jnp.concatenate([k for k, _ in pieces], axis=1)
        v_all = jnp.concatenate([v for _, v in pieces], axis=1)
        valid_all = jnp.concatenate(list(valids), axis=1)
        out, mass = ops.synapse_attention(q, k_all, v_all, valid_all, scale=scale)
        splits = list(np.cumsum(sizes))[:-1]
        return out, list(jnp.split(mass, splits, axis=1))

    def body(q, *flat):
        n = len(pieces)
        ks, vs, ms = flat[:n], flat[n : 2 * n], flat[2 * n :]
        k_loc = jnp.concatenate(ks, axis=1)
        v_loc = jnp.concatenate(vs, axis=1)
        valid_loc = jnp.concatenate(ms, axis=1)
        qg = q.reshape(B, Hkv, G, D)
        s = jnp.einsum("bkgd,btkd->bkgt", qg, k_loc).astype(jnp.float32) * scale
        s = jnp.where(valid_loc[:, None, None, :], s, NEG_INF)
        m = jax.lax.pmax(jnp.max(s, axis=-1), axis)
        e = jnp.exp(s - m[..., None])
        denom = jax.lax.psum(jnp.sum(e, axis=-1), axis)
        p = e / denom[..., None]
        out = jax.lax.psum(
            jnp.einsum("bkgt,btkd->bkgd", p.astype(v_loc.dtype), v_loc), axis
        )
        mass_loc = p.sum(axis=(1, 2))
        splits = list(np.cumsum([k.shape[1] for k in ks]))[:-1]
        masses = jnp.split(mass_loc, splits, axis=1)
        return (out.reshape(B, H, D), *masses)

    from jax.sharding import PartitionSpec as P

    mesh = _resolve(ctx).mesh
    if mesh is None:
        raise ValueError("piece_attend: ShardContext has an axis but no mesh")
    tok = P(None, axis, None, None)
    tokm = P(None, axis)
    rep3 = P(None, None, None)
    in_specs = (rep3, *([tok] * len(pieces)), *([tok] * len(pieces)), *([tokm] * len(pieces)))
    out_specs = (rep3, *([tokm] * len(pieces)))
    flat = [k for k, _ in pieces] + [v for _, v in pieces] + list(valids)
    res = shard_map_nocheck(body, mesh, in_specs, out_specs)(q, *flat)
    return res[0], list(res[1:])
