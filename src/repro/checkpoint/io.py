"""Checkpointing: msgpack + zstd pytree serialization (no orbax).

Arrays are stored as (dtype, shape, raw bytes); the tree structure is
round-tripped via flatten-with-path so arbitrary nested dict/list/dataclass
param trees survive.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: only save/load need it
    zstandard = None


def _require_zstd():
    if zstandard is None:
        raise ModuleNotFoundError(
            "zstandard is required for checkpoint save/load (pip install zstandard)"
        )


def _encode_tree(tree) -> bytes:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = []
    for path, leaf in leaves_with_paths:
        arr = np.asarray(leaf)
        payload.append(
            {
                "path": jax.tree_util.keystr(path),
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "data": arr.tobytes(),
            }
        )
    return msgpack.packb(payload, use_bin_type=True)


def save(path: str, tree, *, level: int = 3) -> None:
    _require_zstd()
    raw = _encode_tree(tree)
    comp = zstandard.ZstdCompressor(level=level).compress(raw)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(comp)
    os.replace(tmp, path)


def load(path: str, like):
    """Restore into the structure of `like` (a pytree with array leaves)."""
    _require_zstd()
    with open(path, "rb") as f:
        raw = zstandard.ZstdDecompressor().decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    by_path = {p["path"]: p for p in payload}
    leaves_with_paths, tdef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_with_paths:
        key = jax.tree_util.keystr(path)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        rec = by_path[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, out)
