"""Checkpointing: msgpack + compressed pytree serialization (no orbax).

Arrays are stored as (dtype, shape, raw bytes); the tree structure is
round-tripped via flatten-with-path so arbitrary nested dict/list/dataclass
param trees survive.

Three layers:

* :func:`dumps` / :func:`loads` — in-memory codec (bytes <-> pytree),
  zstd-compressed (requires the optional ``zstandard`` dep).
* :func:`dumps_framed` / :func:`loads_framed` — the FRAMED cold-blob format
  (ISSUE 8): a fixed header (magic + version + codec + hash id) carrying an
  integrity digest of the compressed payload plus an optional metadata
  section with its own checksum. Readers verify before decoding, so a torn
  write, a truncated file, or a flipped bit surfaces as a typed
  :class:`CorruptBlobError` instead of a msgpack/zstd exception (or worse,
  silently wrong bytes) mid-wake. The codec falls back to stdlib ``zlib``
  when ``zstandard`` is missing, so the cold tier works — and its failure
  machinery is testable — on bare containers.
* :func:`save` / :func:`load` — file wrappers over the zstd codec (atomic
  rename on save).
"""
from __future__ import annotations

import os
import struct
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: only the codec entry points need it
    zstandard = None

try:
    import xxhash
except ImportError:  # optional: frames fall back to crc32
    xxhash = None


class CorruptBlobError(ValueError):
    """A framed blob failed integrity verification (bad magic/version,
    truncation, length mismatch, or checksum mismatch). The payload must
    not be trusted; the cold tier quarantines the file instead of raising
    a decoder error mid-wake."""


def _require_zstd():
    if zstandard is None:
        raise ModuleNotFoundError(
            "zstandard is required for checkpoint save/load (pip install zstandard)"
        )


def _encode_tree(tree) -> bytes:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = []
    for path, leaf in leaves_with_paths:
        arr = np.asarray(leaf)
        payload.append(
            {
                "path": jax.tree_util.keystr(path),
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "data": arr.tobytes(),
            }
        )
    return msgpack.packb(payload, use_bin_type=True)


def _decode_tree(raw: bytes, like, *, numpy: bool = False):
    """Rebuild the pytree of `like` from an encoded payload.

    ``like`` supplies structure only — its leaves may be real arrays or
    abstract ``jax.ShapeDtypeStruct``s (the cold tier keeps just the
    skeleton in RAM). ``numpy=True`` returns numpy leaves (no device
    transfer) — the warm-tier restore path.
    """
    payload = msgpack.unpackb(raw, raw=False)
    by_path = {p["path"]: p for p in payload}
    leaves_with_paths, tdef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, _ in leaves_with_paths:
        key = jax.tree_util.keystr(path)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        rec = by_path[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        out.append(arr if numpy else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, out)


def dumps(tree, *, level: int = 3) -> bytes:
    """Serialize a pytree to a compressed blob (msgpack + zstd)."""
    _require_zstd()
    return zstandard.ZstdCompressor(level=level).compress(_encode_tree(tree))


def loads(data: bytes, like, *, numpy: bool = False):
    """Restore a pytree from a :func:`dumps` blob into the structure of
    `like` (arrays or ShapeDtypeStructs). Raises KeyError on missing leaves."""
    _require_zstd()
    raw = zstandard.ZstdDecompressor().decompress(data)
    return _decode_tree(raw, like, numpy=numpy)


# ---------------------------------------------------------------------------
# Framed cold-blob format (ISSUE 8): integrity-checked, versioned container.
#
#   magic(4) version(u8) codec(u8) hash_id(u8) reserved(u8)
#   meta_len(u32) payload_len(u64) meta_crc32(u32) payload_digest(u64)
#   [meta bytes] [payload bytes]
#
# The digest covers the COMPRESSED payload, so verification never feeds
# untrusted bytes to the decompressor. ``meta`` is an opaque caller section
# (the SynapseStore stores pickled skeleton/bookkeeping there) checked by
# its own crc32 — recovery can read header+meta without touching the
# payload of every blob.
# ---------------------------------------------------------------------------
FRAME_MAGIC = b"WCSB"
FRAME_VERSION = 1
_FRAME_HDR = struct.Struct("<4sBBBBIQIQ")
FRAME_HEADER_BYTES = _FRAME_HDR.size

CODEC_ZLIB, CODEC_ZSTD = 0, 1
HASH_CRC32, HASH_XXH64 = 0, 1
_CODEC_NAMES = {CODEC_ZLIB: "zlib", CODEC_ZSTD: "zstd"}


def default_codec() -> int:
    """zstd when the optional dep is present, stdlib zlib otherwise — the
    cold tier is never silently disabled by a missing compressor."""
    return CODEC_ZSTD if zstandard is not None else CODEC_ZLIB


def _default_hash_id() -> int:
    return HASH_XXH64 if xxhash is not None else HASH_CRC32


def _digest(data: bytes, hash_id: int) -> int:
    if hash_id == HASH_XXH64:
        if xxhash is None:
            raise CorruptBlobError(
                "blob digest uses xxh64 but xxhash is not installed: "
                "cannot verify integrity"
            )
        return xxhash.xxh64(data).intdigest()
    if hash_id == HASH_CRC32:
        return zlib.crc32(data) & 0xFFFFFFFF
    raise CorruptBlobError(f"unknown blob hash id {hash_id}")


def _compress(raw: bytes, codec: int, level: int) -> bytes:
    if codec == CODEC_ZSTD:
        _require_zstd()
        return zstandard.ZstdCompressor(level=level).compress(raw)
    if codec == CODEC_ZLIB:
        return zlib.compress(raw, min(9, max(1, level)))
    raise ValueError(f"unknown blob codec {codec}")


def _decompress(payload: bytes, codec: int) -> bytes:
    if codec == CODEC_ZSTD:
        _require_zstd()
        return zstandard.ZstdDecompressor().decompress(payload)
    if codec == CODEC_ZLIB:
        return zlib.decompress(payload)
    raise CorruptBlobError(f"unknown blob codec {codec}")


def frame(payload: bytes, *, meta: bytes = b"", codec: int | None = None,
          hash_id: int | None = None) -> bytes:
    """Wrap compressed ``payload`` (and an opaque ``meta`` section) in the
    checksummed frame header."""
    codec = default_codec() if codec is None else codec
    hash_id = _default_hash_id() if hash_id is None else hash_id
    hdr = _FRAME_HDR.pack(
        FRAME_MAGIC, FRAME_VERSION, codec, hash_id, 0,
        len(meta), len(payload), zlib.crc32(meta) & 0xFFFFFFFF,
        _digest(payload, hash_id),
    )
    return hdr + meta + payload


def parse_frame_header(data: bytes) -> dict:
    """Validate and unpack the fixed header (magic/version/lengths only —
    no digest check; see :func:`unframe`). Raises :class:`CorruptBlobError`
    on anything that cannot be a well-formed current-version frame."""
    if len(data) < FRAME_HEADER_BYTES:
        raise CorruptBlobError(
            f"truncated blob: {len(data)} bytes < {FRAME_HEADER_BYTES}-byte header"
        )
    magic, version, codec, hash_id, _, meta_len, payload_len, meta_crc, digest = (
        _FRAME_HDR.unpack_from(data)
    )
    if magic != FRAME_MAGIC:
        raise CorruptBlobError(f"bad blob magic {magic!r}")
    if version != FRAME_VERSION:
        raise CorruptBlobError(f"unsupported blob version {version}")
    if codec not in _CODEC_NAMES:
        raise CorruptBlobError(f"unknown blob codec {codec}")
    return {
        "codec": codec, "hash_id": hash_id, "meta_len": meta_len,
        "payload_len": payload_len, "meta_crc": meta_crc, "digest": digest,
    }


def unframe(data: bytes, *, verify: bool = True) -> tuple[bytes, bytes, int]:
    """Split a framed blob into ``(meta, payload, codec)``, verifying
    lengths and checksums. ``verify=False`` skips the payload digest (the
    bench's A/B arm measuring verification overhead) but still validates
    structure."""
    hdr = parse_frame_header(data)
    expected = FRAME_HEADER_BYTES + hdr["meta_len"] + hdr["payload_len"]
    if len(data) != expected:
        raise CorruptBlobError(
            f"truncated/oversized blob: {len(data)} bytes, header says {expected}"
        )
    meta = data[FRAME_HEADER_BYTES:FRAME_HEADER_BYTES + hdr["meta_len"]]
    payload = data[FRAME_HEADER_BYTES + hdr["meta_len"]:]
    if (zlib.crc32(meta) & 0xFFFFFFFF) != hdr["meta_crc"]:
        raise CorruptBlobError("blob metadata checksum mismatch")
    if verify and _digest(payload, hdr["hash_id"]) != hdr["digest"]:
        raise CorruptBlobError("blob payload checksum mismatch")
    return meta, payload, hdr["codec"]


def read_frame_meta(path: str) -> bytes:
    """Read and verify ONLY the header + metadata section of a framed blob
    file (cheap: no payload read, no decompression). The file's size is
    checked against the header so truncation is still caught. Used by
    `SynapseStore.recover` to rebuild the cold index after a crash."""
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        hdr_bytes = f.read(FRAME_HEADER_BYTES)
        hdr = parse_frame_header(hdr_bytes)
        expected = FRAME_HEADER_BYTES + hdr["meta_len"] + hdr["payload_len"]
        if size != expected:
            raise CorruptBlobError(
                f"truncated/oversized blob file: {size} bytes, header says {expected}"
            )
        meta = f.read(hdr["meta_len"])
    if len(meta) != hdr["meta_len"] or (zlib.crc32(meta) & 0xFFFFFFFF) != hdr["meta_crc"]:
        raise CorruptBlobError("blob metadata checksum mismatch")
    return meta


def dumps_framed(tree, *, level: int = 3, meta: bytes = b"",
                 codec: int | None = None) -> bytes:
    """Serialize a pytree into the framed, integrity-checked cold format."""
    codec = default_codec() if codec is None else codec
    return frame(_compress(_encode_tree(tree), codec, level), meta=meta, codec=codec)


def loads_framed(data: bytes, like, *, numpy: bool = False, verify: bool = True):
    """Restore a pytree from a :func:`dumps_framed` blob, verifying the
    frame first. Raises :class:`CorruptBlobError` on any integrity failure
    and KeyError on missing leaves (like :func:`loads`)."""
    _, payload, codec = unframe(data, verify=verify)
    try:
        raw = _decompress(payload, codec)
    except CorruptBlobError:
        raise
    except Exception as e:  # zlib.error / ZstdError: corrupt despite digest?
        raise CorruptBlobError(f"blob payload undecompressable: {e}") from e
    try:
        return _decode_tree(raw, like, numpy=numpy)
    except KeyError:
        raise  # missing-leaf contract stays a KeyError (schema, not bytes)
    except Exception as e:
        # with verify=False a flipped bit can land here instead of upstream
        raise CorruptBlobError(f"blob payload undecodable: {e}") from e


def save(path: str, tree, *, level: int = 3) -> None:
    comp = dumps(tree, level=level)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(comp)
    os.replace(tmp, path)


def load(path: str, like, *, numpy: bool = False):
    """Restore into the structure of `like` (a pytree with array leaves)."""
    _require_zstd()
    with open(path, "rb") as f:
        data = f.read()
    return loads(data, like, numpy=numpy)
