"""Checkpointing: msgpack + zstd pytree serialization (no orbax).

Arrays are stored as (dtype, shape, raw bytes); the tree structure is
round-tripped via flatten-with-path so arbitrary nested dict/list/dataclass
param trees survive.

Two layers:

* :func:`dumps` / :func:`loads` — in-memory codec (bytes <-> pytree). The
  tiered synapse memory's cold tier stores these blobs on disk, one per
  hibernated agent, with only a shape/dtype skeleton kept in host RAM.
* :func:`save` / :func:`load` — file wrappers over the same codec (atomic
  rename on save).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: only the codec entry points need it
    zstandard = None


def _require_zstd():
    if zstandard is None:
        raise ModuleNotFoundError(
            "zstandard is required for checkpoint save/load (pip install zstandard)"
        )


def _encode_tree(tree) -> bytes:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = []
    for path, leaf in leaves_with_paths:
        arr = np.asarray(leaf)
        payload.append(
            {
                "path": jax.tree_util.keystr(path),
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "data": arr.tobytes(),
            }
        )
    return msgpack.packb(payload, use_bin_type=True)


def _decode_tree(raw: bytes, like, *, numpy: bool = False):
    """Rebuild the pytree of `like` from an encoded payload.

    ``like`` supplies structure only — its leaves may be real arrays or
    abstract ``jax.ShapeDtypeStruct``s (the cold tier keeps just the
    skeleton in RAM). ``numpy=True`` returns numpy leaves (no device
    transfer) — the warm-tier restore path.
    """
    payload = msgpack.unpackb(raw, raw=False)
    by_path = {p["path"]: p for p in payload}
    leaves_with_paths, tdef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, _ in leaves_with_paths:
        key = jax.tree_util.keystr(path)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        rec = by_path[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        out.append(arr if numpy else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, out)


def dumps(tree, *, level: int = 3) -> bytes:
    """Serialize a pytree to a compressed blob (msgpack + zstd)."""
    _require_zstd()
    return zstandard.ZstdCompressor(level=level).compress(_encode_tree(tree))


def loads(data: bytes, like, *, numpy: bool = False):
    """Restore a pytree from a :func:`dumps` blob into the structure of
    `like` (arrays or ShapeDtypeStructs). Raises KeyError on missing leaves."""
    _require_zstd()
    raw = zstandard.ZstdDecompressor().decompress(data)
    return _decode_tree(raw, like, numpy=numpy)


def save(path: str, tree, *, level: int = 3) -> None:
    comp = dumps(tree, level=level)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(comp)
    os.replace(tmp, path)


def load(path: str, like, *, numpy: bool = False):
    """Restore into the structure of `like` (a pytree with array leaves)."""
    _require_zstd()
    with open(path, "rb") as f:
        data = f.read()
    return loads(data, like, numpy=numpy)
