"""Train step + loss; pjit-able and remat-aware.

``make_train_step(cfg, opt_cfg)`` returns a pure (state, batch) -> (state,
metrics) function suitable for jax.jit with shardings (launch/train.py wires
the mesh).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw


@dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array


jax.tree_util.register_dataclass(TrainState, data_fields=["params", "opt", "step"], meta_fields=[])


def init_train_state(key, cfg: ModelConfig) -> TrainState:
    params = model_lib.init_params(key, cfg)
    return TrainState(params=params, opt=init_adamw(params), step=jnp.zeros((), jnp.int32))


def abstract_train_state(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_train_state(jax.random.key(0), cfg))


def cross_entropy(logits, labels, mask=None):
    """logits [B,S,V] f32, labels [B,S] int32; mean over valid tokens."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: {"tokens"|"embeds", "labels", optional "mask", "positions"}."""
    inputs = {k: batch[k] for k in ("tokens", "embeds", "positions") if k in batch}
    logits, aux = model_lib.forward(params, cfg, inputs)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    loss = ce + cfg.router_aux_coef * aux["lb_loss"]
    metrics = {"loss": loss, "ce": ce, "lb_loss": aux["lb_loss"], "drop_frac": aux["drop_frac"]}
    return loss, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    def train_step(state: TrainState, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, cfg, batch
        )
        new_params, new_opt, opt_metrics = adamw_update(opt_cfg, state.params, grads, state.opt)
        metrics.update(opt_metrics)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, cfg, batch)
        return metrics

    return eval_step
