"""AdamW + schedules + global-norm clipping, from scratch (no optax).

Pytree-generic; optimizer state mirrors the param tree (m, v in fp32).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"  # cosine | constant


@dataclass
class AdamWState:
    m: Any
    v: Any
    step: jax.Array


jax.tree_util.register_dataclass(AdamWState, data_fields=["m", "v", "step"], meta_fields=[])


def init_adamw(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), t)
    return AdamWState(m=zeros(params), v=zeros(params), step=jnp.zeros((), jnp.int32))


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(a.astype(jnp.float32))) for a in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        is_matrix = p.ndim >= 2  # decay matrices only (norms/bias exempt)
        decay = cfg.weight_decay * p.astype(jnp.float32) if is_matrix else 0.0
        new_p = p.astype(jnp.float32) - lr * (delta + decay)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, step), {"grad_norm": gnorm, "lr": lr}
