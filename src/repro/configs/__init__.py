"""Architecture registry: one module per assigned architecture.

``get_config("qwen3-8b")`` returns the exact assigned ModelConfig;
``get_config("qwen3-8b", reduced=True)`` the CPU smoke variant.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

# arch id (CLI --arch) -> module name
ARCHS = {
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen1.5-110b": "qwen1p5_110b",
    "qwen3-8b": "qwen3_8b",
    "hubert-xlarge": "hubert_xlarge",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-4b": "qwen3_4b",
    "smollm-135m": "smollm_135m",
    # the paper's own evaluation model (Qwen2.5-0.5B-Instruct)
    "qwen2.5-0.5b": "qwen25_0p5b",
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def list_archs() -> list[str]:
    return list(ARCHS)
