"""RWKV6 "Finch" 1.6B [arXiv:2404.05892] — attention-free, data-dep decay."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # = rwkv heads (d_model / head_size)
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_kind="rwkv6",
    rwkv_head_size=64,
    rope_kind="none",
)
