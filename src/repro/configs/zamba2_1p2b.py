"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone + shared attention block.

The shared transformer block (applied every 6 mamba layers, per-invocation
LoRA on qkv) is itself an instance of singleton weight sharing — see
DESIGN.md §4.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,       # MHA in the shared block
    d_head=64,
    d_ff=8192,
    vocab_size=32000,
    block_kind="mamba2",
    ssm_state_size=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=6,
    shared_attn_lora_rank=128,
    rope_theta=10_000.0,
)
