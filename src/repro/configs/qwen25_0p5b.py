"""Qwen2.5-0.5B-Instruct — the paper's own evaluation model (§5.2)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-0.5b",
    arch_type="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_head=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
