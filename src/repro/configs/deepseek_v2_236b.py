"""DeepSeek-V2 236B [arXiv:2405.04434] — MLA (kv_lora=512) + 2 shared/160
routed experts top-6; first layer dense."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,           # per-expert intermediate size
    vocab_size=102400,
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
    n_experts=160,
    n_shared_experts=2,
    experts_per_token=6,
    first_k_dense=1,
    dense_d_ff=12288,
)
