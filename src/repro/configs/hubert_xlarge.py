"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only audio transformer.

Conv/mel frontend is stubbed: input_specs() provides frame embeddings.
vocab=504 is the k-means target codebook (masked-prediction training).
No decode shapes (encoder-only) — see DESIGN.md §4.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    rope_kind="none",
    embed_inputs=False,
)
