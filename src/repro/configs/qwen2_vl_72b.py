"""Qwen2-VL-72B [arXiv:2409.12191] — M-RoPE decoder; vision frontend stubbed.

input_specs() feeds precomputed patch+text embeddings (DESIGN.md carve-out);
the decoder still owns the embedding table + lm head for text decode.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab_size=152064,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    embed_inputs=False,
)
