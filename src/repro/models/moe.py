"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Dispatch strategy (see DESIGN.md): tokens are flattened, argsorted by their
assigned expert, and scattered into a static ``[E, C, d]`` buffer (capacity
C = tokens * top_k / E * capacity_factor; overflow drops, counted for the
aux metrics). Expert matmuls are then plain batched GEMMs ``[E,C,d]x[E,d,f]``
which shard cleanly over the ``model`` mesh axis (expert parallelism) under
GSPMD — no [T, E, C] one-hot intermediate is ever materialized.

Covers qwen3-moe (128e top-8, no shared) and deepseek-v2 (160e top-6 +
2 shared experts, leading dense layer handled at the model level).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, swiglu, swiglu_init


def _constrain_ep(x, expert_dim: int):
    """Pin the expert dim of dispatch buffers to the model axis (expert
    parallelism) — GSPMD otherwise gathers the expert weights per layer."""
    from repro.models import model as model_lib  # lazy: no import cycle

    spec = getattr(model_lib, "_ACT_SPEC", None)
    if spec is None:
        return x
    import jax.sharding as jsh

    axes = [None] * x.ndim
    axes[0] = spec[0]          # batch axes
    axes[expert_dim] = "model"
    return jax.lax.with_sharding_constraint(x, jsh.PartitionSpec(*axes))


def moe_init(key, cfg: ModelConfig, dtype):
    kr, ke, ks = jax.random.split(key, 3)
    E, dm, dff = cfg.n_experts, cfg.d_model, cfg.d_ff
    keys = jax.random.split(ke, 3)
    p = {
        "router": dense_init(kr, dm, E, dtype, scale=0.02),
        "experts": {
            "gate": jax.vmap(lambda k: dense_init(k, dm, dff, dtype))(jax.random.split(keys[0], E)),
            "up": jax.vmap(lambda k: dense_init(k, dm, dff, dtype))(jax.random.split(keys[1], E)),
            "down": jax.vmap(lambda k: dense_init(k, dff, dm, dtype))(jax.random.split(keys[2], E)),
        },
    }
    if cfg.n_shared_experts:
        shared_ff = cfg.n_shared_experts * cfg.d_ff
        p["shared"] = swiglu_init(ks, dm, shared_ff, dtype)
    return p


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.experts_per_token / cfg.n_experts * cfg.moe_capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_forward(p, cfg: ModelConfig, x):
    """x: [B, S, dm] -> (y, aux) where aux has the load-balance loss terms.

    Two dispatch strategies (cfg.moe_dispatch, see EXPERIMENTS.md §Perf):
      * "per_lane": sort/scatter batched over the batch dim — every dispatch
        op carries the sharded batch axis, so GSPMD keeps it distributed
        (no replicated global sort). Default.
      * "global": one flat sort over B*S*K assignments — statistically
        smoother capacity, but the sort/gather is unshardable and SPMD
        replicates it (measured 10x memory-term blowup on MoE train).
    Decode (S == 1) always uses the global path (per-lane capacity would
    degenerate).
    """
    B, S, dm = x.shape
    if cfg.moe_dispatch == "per_lane" and S > 1:
        return _moe_per_lane(p, cfg, x)
    return _moe_global(p, cfg, x)


def _moe_global(p, cfg: ModelConfig, x):
    B, S, dm = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, dm)

    logits = (xt @ p["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # [T, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- sort-based dispatch into [E, C, dm] ----
    C = _capacity(cfg, T)
    flat_e = expert_ids.reshape(T * K)
    order = jnp.argsort(flat_e, stable=True)  # [T*K]
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)  # [E]
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * K) - starts[sorted_e]  # rank within expert
    src_token = order // K

    buf = jnp.zeros((E, C, dm), xt.dtype)
    buf = buf.at[sorted_e, pos_in_e].set(xt[src_token].astype(buf.dtype), mode="drop")

    # ---- batched expert GEMMs (shard over E) ----
    ex = p["experts"]
    cast = lambda a: a.astype(buf.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, cast(ex["gate"]))) * jnp.einsum(
        "ecd,edf->ecf", buf, cast(ex["up"])
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, cast(ex["down"]))  # [E, C, dm]

    # ---- gather back, weight, combine over K ----
    gathered = out_buf[sorted_e, pos_in_e]  # [T*K, dm] (overflowed -> garbage)
    kept = pos_in_e < C
    gathered = jnp.where(kept[:, None], gathered, jnp.zeros((), gathered.dtype))
    unsorted = jnp.zeros((T * K, dm), xt.dtype).at[order].set(gathered)
    w = gate_vals.reshape(T * K).astype(xt.dtype)
    y = (unsorted * w[:, None]).reshape(T, K, dm).sum(axis=1)

    if cfg.n_shared_experts:
        y = y + swiglu(p["shared"], xt)

    # ---- aux: switch-style load-balance loss + drop fraction ----
    frac_tokens = jnp.mean(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=(0, 1)) * K
    frac_probs = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    dropped = 1.0 - jnp.mean(kept.astype(jnp.float32))
    aux = {"lb_loss": lb_loss, "drop_frac": dropped}
    return y.reshape(B, S, dm), aux


def _moe_per_lane(p, cfg: ModelConfig, x):
    """Batched-over-lanes, GATHER-ONLY dispatch: [B, S, dm], per-lane capacity.

    No scatter anywhere: after the per-lane sort, the [E, C] buffer is read
    as contiguous slices of the sorted token stream (buf[e, c] =
    x_sorted[starts[e] + c]), and the combine/unsort are take_along_axis.
    Batched gathers over a batch-sharded dim partition cleanly under GSPMD;
    batched scatters trigger involuntary full rematerialization
    (EXPERIMENTS.md §Perf pair 3, iteration 2).
    """
    B, S, dm = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # [B,S,K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    N = S * K
    C = max(8, -(-int(S * K / E * cfg.moe_capacity_factor) // 8) * 8)
    flat_e = expert_ids.reshape(B, N)
    order = jnp.argsort(flat_e, axis=-1, stable=True)           # [B,N]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jax.vmap(lambda fe: jnp.bincount(fe, length=E))(flat_e)  # [B,E]
    starts = jnp.cumsum(counts, axis=-1) - counts
    pos_sorted = jnp.arange(N)[None, :] - jnp.take_along_axis(starts, sorted_e, axis=-1)
    src_token = order // K                                       # [B,N]
    x_sorted = jnp.take_along_axis(x, src_token[..., None], axis=1)  # [B,N,dm]

    # gather-only buffer build: buf[b,e,c] = x_sorted[b, starts[b,e]+c]
    slot_idx = starts[:, :, None] + jnp.arange(C)[None, None, :]          # [B,E,C]
    slot_valid = jnp.arange(C)[None, None, :] < counts[:, :, None]
    slot_idx = jnp.clip(slot_idx, 0, N - 1)
    buf = jnp.take_along_axis(
        x_sorted, slot_idx.reshape(B, E * C)[..., None], axis=1
    ).reshape(B, E, C, dm)
    buf = jnp.where(slot_valid[..., None], buf, jnp.zeros((), buf.dtype))
    buf = _constrain_ep(buf, expert_dim=1)

    ex = p["experts"]
    cast = lambda a: a.astype(buf.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, cast(ex["gate"]))) * jnp.einsum(
        "becd,edf->becf", buf, cast(ex["up"])
    )
    h = _constrain_ep(h, expert_dim=1)
    out_buf = _constrain_ep(jnp.einsum("becf,efd->becd", h, cast(ex["down"])), expert_dim=1)

    # combine: token n reads buf[sorted_e[n], pos_sorted[n]], then unsort
    kept = pos_sorted < C
    flat_pos = sorted_e * C + jnp.minimum(pos_sorted, C - 1)     # [B,N]
    gathered = jnp.take_along_axis(
        out_buf.reshape(B, E * C, dm), flat_pos[..., None], axis=1
    )
    gathered = jnp.where(kept[..., None], gathered, jnp.zeros((), gathered.dtype))
    inv_order = jnp.argsort(order, axis=-1)
    unsorted = jnp.take_along_axis(gathered, inv_order[..., None], axis=1)
    w = gate_vals.reshape(B, N).astype(x.dtype)
    y = (unsorted * w[..., None]).reshape(B, S, K, dm).sum(axis=2)

    if cfg.n_shared_experts:
        y = y + swiglu(jax.tree.map(lambda a: a.astype(x.dtype), p["shared"]), x)

    frac_tokens = jnp.mean(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=(0, 1, 2)) * K
    frac_probs = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    dropped = 1.0 - jnp.mean(kept.astype(jnp.float32))
    return y, {"lb_loss": lb_loss, "drop_frac": dropped}
