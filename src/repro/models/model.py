"""Unified model: one forward/prefill/decode covering all assigned archs.

The layer stack is executed as a sequence of *segments*: each segment is a
``lax.scan`` over a homogeneous slice of stacked per-layer params, optionally
followed by a shared-attention invocation (zamba2 hybrid). This keeps HLO
size independent of depth (80-layer models on 512 devices) while allowing
heterogeneous patterns without cond-in-scan.

Cache layout mirrors the segments: per-group stacked cache pytrees (leading
layer axis) consumed as scan xs/ys, plus per-invocation shared-attn caches.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import synapse as synapse_lib
from repro.models import attention, cache as cache_lib, mamba2, mla, moe, rwkv6
from repro.models.config import LayerGroup, ModelConfig
from repro.models.layers import dense_init, embed_init, rms_norm, rms_norm_init, swiglu, swiglu_init


# ---------------------------------------------------------------------------
# cache configuration (runtime, not architecture)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CacheSpec:
    kind: str = "full"            # full | synapse
    capacity: int = 4096          # full-cache slots (>= prompt + decode budget)
    n_landmarks: int = 64         # synapse: K
    window: int = 128             # synapse: W
    n_inject: int = 8             # synapse: J (referential-injection slots)
    policy: synapse_lib.SynapsePolicy = field(default_factory=synapse_lib.SynapsePolicy)


@dataclass
class ModelCaches:
    """Decode state for the whole stack."""

    groups: tuple          # per layer-group stacked cache pytree
    shared: Any            # zamba2: stacked per-invocation attn caches (or None)


jax.tree_util.register_dataclass(ModelCaches, data_fields=["groups", "shared"], meta_fields=[])


# ---------------------------------------------------------------------------
# segment plan
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Segment:
    group: int        # index into layer groups / params["groups"]
    start: int        # start layer within the group's stacked params
    count: int
    shared_after: int  # shared-attn invocation index after this segment, or -1


def build_segments(cfg: ModelConfig) -> list[Segment]:
    segs: list[Segment] = []
    groups = cfg.layer_groups()
    if cfg.shared_attn_every > 0:
        assert len(groups) == 1
        every, total = cfg.shared_attn_every, groups[0].count
        start = inv = 0
        while start < total:
            count = min(every, total - start)
            has_inv = (start + count) % every == 0 and (start + count) <= total and inv < cfg.n_shared_attn_invocations
            segs.append(Segment(0, start, count, inv if has_inv else -1))
            if has_inv:
                inv += 1
            start += count
        return segs
    return [Segment(g, 0, grp.count, -1) for g, grp in enumerate(groups)]


# ---------------------------------------------------------------------------
# per-layer block init / apply
# ---------------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig, grp: LayerGroup, dtype):
    ks = jax.random.split(key, 4)
    if grp.kind == "attn":
        p = {"ln1": rms_norm_init(cfg.d_model, dtype), "ln2": rms_norm_init(cfg.d_model, dtype)}
        if cfg.attn_kind == "mla":
            p["attn"] = mla.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = attention.attn_init(ks[0], cfg, dtype)
        if grp.mlp == "moe":
            p["mlp"] = moe.moe_init(ks[1], cfg, dtype)
        else:
            # dense MLP; inside a MoE model (first_k_dense) it uses dense_d_ff
            dff = cfg.d_ff if not cfg.is_moe else (cfg.dense_d_ff or cfg.d_ff * cfg.experts_per_token)
            p["mlp"] = swiglu_init(ks[1], cfg.d_model, dff, dtype)
        return p
    if grp.kind == "mamba2":
        return {"ln": rms_norm_init(cfg.d_model, dtype), "mixer": mamba2.mamba2_init(ks[0], cfg, dtype)}
    if grp.kind == "rwkv6":
        return {
            "ln1": rms_norm_init(cfg.d_model, dtype),
            "tmix": rwkv6.rwkv6_tmix_init(ks[0], cfg, dtype),
            "ln2": rms_norm_init(cfg.d_model, dtype),
            "cmix": rwkv6.rwkv6_cmix_init(ks[1], cfg, dtype),
        }
    raise ValueError(grp.kind)


def _shared_attn_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rms_norm_init(cfg.d_model, dtype),
        "attn": attention.attn_init(k1, cfg, dtype, n_lora=cfg.n_shared_attn_invocations),
        "ln2": rms_norm_init(cfg.d_model, dtype),
        "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    groups = cfg.layer_groups()
    params: dict = {}
    if cfg.embed_inputs or not cfg.is_encoder_only:
        params["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    stacked = []
    for g, grp in enumerate(groups):
        layer_keys = jax.random.split(keys[1 + g % 4], grp.count)
        stacked.append(jax.vmap(lambda k: _block_init(k, cfg, grp, dtype))(layer_keys))
    params["groups"] = stacked
    if cfg.shared_attn_every > 0:
        params["shared_attn"] = _shared_attn_init(keys[5], cfg, dtype)
    params["final_norm"] = rms_norm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[6], cfg.d_model, cfg.vocab_size, dtype)
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of params — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# full-sequence forward (training / prefill trunk)
# ---------------------------------------------------------------------------

# Optional activation PartitionSpec (batch axes), set by launch/ entry points
# before tracing under a mesh. GSPMD propagates well from these anchors.
_ACT_SPEC = None


def set_activation_sharding(spec):
    """spec: PartitionSpec for [B, S, d] activations (or None to disable)."""
    global _ACT_SPEC
    _ACT_SPEC = spec


def _constrain(x):
    if _ACT_SPEC is None:
        return x
    import jax.sharding as jsh
    spec = _ACT_SPEC
    if x.ndim == 2:  # [B, d] decode stream
        spec = jsh.PartitionSpec(spec[0])
    return jax.lax.with_sharding_constraint(x, spec)


def _radd(x, y):
    """Residual add keeping the stream dtype (params may be fp32)."""
    return x + y.astype(x.dtype)


def cast_params(params, cfg: ModelConfig):
    """Cast float params to compute dtype at entry (fp32 masters stay with
    the optimizer). Keeps matmul FLOPs in bf16 on TPU.

    Already-cast trees (the engine pre-casts once and calls decode_step
    twice per virtual tick of the scanned macro window) short-circuit at
    trace time — no per-leaf astype graph building inside the scan body."""
    compute = jnp.dtype(cfg.compute_dtype)
    if all(
        not jnp.issubdtype(a.dtype, jnp.floating) or a.dtype == compute
        for a in jax.tree.leaves(params)
    ):
        return params
    return jax.tree.map(
        lambda a: a.astype(compute) if jnp.issubdtype(a.dtype, jnp.floating) else a, params
    )

def _attn_block_fwd(p, cfg: ModelConfig, grp_mlp: str, x, positions, *, lora_idx=None, chunk=1024):
    """Returns (x_out, aux, kv) — kv is (k_rot, v) or (ckv, krope) for MLA."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        y, kv = mla.mla_forward(p["attn"], cfg, h, positions, chunk=chunk)
    else:
        y, kv = attention.attention_forward(p["attn"], cfg, h, positions, lora_idx=lora_idx, chunk=chunk)
    x = _radd(x, y)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if grp_mlp == "moe":
        y, aux = moe.moe_forward(p["mlp"], cfg, h)
    else:
        y, aux = swiglu(p["mlp"], h), {"lb_loss": jnp.zeros((), jnp.float32), "drop_frac": jnp.zeros((), jnp.float32)}
    return _radd(x, y), aux, kv


def _zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32), "drop_frac": jnp.zeros((), jnp.float32)}


def _block_fwd(p, cfg: ModelConfig, grp: LayerGroup, x, positions, chunk):
    if grp.kind == "attn":
        out, aux, _ = _attn_block_fwd(p, cfg, grp.mlp, x, positions, chunk=chunk)
        return out, aux
    if grp.kind == "mamba2":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        return _radd(x, mamba2.mamba2_forward(p["mixer"], cfg, h)), _zero_aux()
    if grp.kind == "rwkv6":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, _ = rwkv6.rwkv6_tmix_forward(p["tmix"], cfg, h)
        x = _radd(x, y)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, _ = rwkv6.rwkv6_cmix_forward(p["cmix"], cfg, h)
        return _radd(x, y), _zero_aux()
    raise ValueError(grp.kind)


def _shared_attn_fwd(p, cfg: ModelConfig, x, positions, lora_idx, chunk):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    y, kv = attention.attention_forward(p["attn"], cfg, h, positions, lora_idx=lora_idx, chunk=chunk)
    x = _radd(x, y)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return _radd(x, swiglu(p["mlp"], h)), kv


def _scan_stack(body, carry, xs, count: int, use_scan: bool):
    """lax.scan or python-unrolled equivalent (roofline probes unroll so
    cost_analysis sees every layer instead of one while body)."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(count):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys) if ys else None
    return carry, stacked


def _slice_group(params_g, start: int, count: int):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + count, axis=0), params_g)


def forward(params, cfg: ModelConfig, inputs: dict, *, chunk: int = 1024):
    """Training/eval forward.

    inputs: {"tokens": [B,S] int32} or {"embeds": [B,S,d]}, optional
    "positions" ([B,S] or [B,3,S] for mrope).
    Returns (logits [B,S,V], aux).
    """
    params = cast_params(params, cfg)
    if "embeds" in inputs:
        x = inputs["embeds"].astype(jnp.dtype(cfg.compute_dtype))
        B, S = x.shape[:2]
    else:
        tokens = inputs["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    if "positions" in inputs:
        positions = inputs["positions"]
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        positions = jnp.broadcast_to(pos[:, None, :], (B, 3, S)) if cfg.rope_kind == "mrope" else pos

    groups = cfg.layer_groups()
    aux_total = _zero_aux()

    def make_body(grp):
        def body(carry, p_layer):
            out, aux = _block_fwd(p_layer, cfg, grp, _constrain(carry), positions, chunk)
            return _constrain(out), aux
        if not cfg.remat:
            return body
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            return jax.checkpoint(body, policy=policy)
        return jax.checkpoint(body)

    for seg in build_segments(cfg):
        grp = groups[seg.group]
        p_seg = _slice_group(params["groups"][seg.group], seg.start, seg.count)
        x, auxs = _scan_stack(make_body(grp), x, p_seg, seg.count, cfg.scan_layers)
        aux_total = jax.tree.map(lambda t, a: t + a.sum(), aux_total, auxs)
        if seg.shared_after >= 0:
            x, _ = _shared_attn_fwd(params["shared_attn"], cfg, x, positions, seg.shared_after, chunk)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    aux_total["hidden_last"] = x[:, -1, :]
    return logits, aux_total


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def _stack(tree, n: int):
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n, *a.shape)), tree)


def init_caches(cfg: ModelConfig, batch: int, spec: CacheSpec) -> ModelCaches:
    dtype = jnp.dtype(cfg.compute_dtype)
    groups = cfg.layer_groups()
    out = []
    for grp in groups:
        if grp.kind == "attn":
            if cfg.attn_kind == "mla":
                c = cache_lib.init_mla_cache(cfg, batch, spec.capacity, dtype)
            elif spec.kind == "synapse":
                c = cache_lib.init_synapse_cache(cfg, batch, spec.n_landmarks, spec.window, spec.n_inject, dtype)
            else:
                c = cache_lib.init_full_cache(cfg, batch, spec.capacity, dtype)
        elif grp.kind == "mamba2":
            c = cache_lib.init_mamba2_state(cfg, batch, dtype)
        elif grp.kind == "rwkv6":
            c = cache_lib.init_rwkv6_state(cfg, batch, dtype)
        out.append(_stack(c, grp.count))
    shared = None
    if cfg.shared_attn_every > 0:
        if spec.kind == "synapse":
            c = cache_lib.init_synapse_cache(cfg, batch, spec.n_landmarks, spec.window, spec.n_inject, dtype)
        else:
            c = cache_lib.init_full_cache(cfg, batch, spec.capacity, dtype)
        shared = _stack(c, cfg.n_shared_attn_invocations)
    return ModelCaches(groups=tuple(out), shared=shared)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------
def _fill_full_cache(cache: cache_lib.FullCache, k, v, positions, length, score=None):
    """Write [B,S,...] prefix into a FullCache."""
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), 0, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, axis=1)
    new_pos = jax.lax.dynamic_update_slice_in_dim(cache.pos, positions, 0, axis=1)
    new_score = cache.score
    if score is not None:
        new_score = jax.lax.dynamic_update_slice_in_dim(cache.score, score, 0, axis=1)
    return cache_lib.FullCache(new_k, new_v, new_pos, new_score, length)


def prefill(params, cfg: ModelConfig, inputs: dict, caches: ModelCaches, *, spec: CacheSpec, chunk: int = 1024):
    """Run the prompt through the stack, filling caches.

    For spec.kind == "synapse", each attention layer's full prompt KV is
    compressed on the fly via hybrid landmark selection (never materializing
    a persistent full cache) — the last-token query is the paper's Q_t.
    Returns (logits_last [B,V], hidden_last [B,d], new_caches).
    """
    params = cast_params(params, cfg)
    assert not cfg.is_encoder_only, "encoder-only archs have no decode/prefill"
    if "embeds" in inputs:
        x = inputs["embeds"].astype(jnp.dtype(cfg.compute_dtype))
        B, S = x.shape[:2]
    else:
        tokens = inputs["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    if "positions" in inputs:
        positions = inputs["positions"]
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        positions = jnp.broadcast_to(pos[:, None, :], (B, 3, S)) if cfg.rope_kind == "mrope" else pos
    pos_scalar = positions[:, 0, :] if cfg.rope_kind == "mrope" else positions
    lengths = jnp.full((B,), S, jnp.int32)

    groups = cfg.layer_groups()

    def attn_body(grp):
        def body(carry, xs):
            p_layer, cache = xs
            carry = _constrain(carry)
            out, _, kv = _attn_block_fwd(p_layer, cfg, grp.mlp, carry, positions, chunk=chunk)
            if cfg.attn_kind == "mla":
                ckv, krope = kv
                new_cache = cache_lib.MLACache(
                    jax.lax.dynamic_update_slice_in_dim(cache.ckv, ckv.astype(cache.ckv.dtype), 0, 1),
                    jax.lax.dynamic_update_slice_in_dim(cache.krope, krope.astype(cache.krope.dtype), 0, 1),
                    cache.score,
                    lengths,
                )
            elif spec.kind == "synapse":
                k_rot, v = kv
                full = cache_lib.FullCache(
                    k_rot.astype(cache.lm_k.dtype), v.astype(cache.lm_v.dtype),
                    pos_scalar, jnp.zeros(pos_scalar.shape, jnp.float32), lengths,
                )
                # paper's Q_t: last-token query of this layer
                q_last = _last_query(p_layer, cfg, carry, positions)
                new_cache = synapse_lib.compress(
                    cfg, full, q_last, cache.n_landmarks, cache.window, cache.n_inject, spec.policy
                )
            else:
                k_rot, v = kv
                q_last = _last_query(p_layer, cfg, carry, positions)
                dens = synapse_lib.attention_density(
                    q_last, k_rot.astype(cache.k.dtype),
                    jnp.ones(k_rot.shape[:2], bool),
                )
                new_cache = _fill_full_cache(cache, k_rot, v, pos_scalar, lengths, score=dens)
            return out, new_cache
        return body

    def ssm_body(grp):
        def body(carry, xs):
            p_layer, _ = xs  # prior state ignored: prefill starts fresh
            carry = _constrain(carry)
            if grp.kind == "mamba2":
                out, new_cache = _mamba2_fwd_state(p_layer, cfg, carry)
            else:
                out, new_cache = _rwkv6_fwd_state(p_layer, cfg, carry)
            return out, new_cache
        return body

    x_cur = x
    seg_caches = list(caches.groups)
    shared_cache = caches.shared
    for seg in build_segments(cfg):
        grp = groups[seg.group]
        p_seg = _slice_group(params["groups"][seg.group], seg.start, seg.count)
        c_seg = _slice_group(seg_caches[seg.group], seg.start, seg.count)
        body = attn_body(grp) if grp.kind == "attn" else ssm_body(grp)
        x_cur, new_c = _scan_stack(body, x_cur, (p_seg, c_seg), seg.count, cfg.scan_layers)
        # write back the updated slice
        seg_caches[seg.group] = jax.tree.map(
            lambda full, part: jax.lax.dynamic_update_slice_in_dim(full, part, seg.start, axis=0),
            seg_caches[seg.group],
            new_c,
        )
        if seg.shared_after >= 0:
            x_before = x_cur
            x_cur, kv = _shared_attn_fwd(params["shared_attn"], cfg, x_cur, positions, seg.shared_after, chunk)
            k_rot, v = kv
            inv_cache = jax.tree.map(lambda a: a[seg.shared_after], shared_cache)
            if spec.kind == "synapse":
                full = cache_lib.FullCache(
                    k_rot.astype(inv_cache.lm_k.dtype), v.astype(inv_cache.lm_v.dtype),
                    pos_scalar, jnp.zeros(pos_scalar.shape, jnp.float32), lengths,
                )
                q_last = _last_query(params["shared_attn"], cfg, x_before, positions, lora_idx=seg.shared_after)
                new_inv = synapse_lib.compress(cfg, full, q_last, inv_cache.n_landmarks, inv_cache.window, inv_cache.n_inject, spec.policy)
            else:
                new_inv = _fill_full_cache(inv_cache, k_rot, v, pos_scalar, lengths)
            shared_cache = jax.tree.map(
                lambda full, part: full.at[seg.shared_after].set(part), shared_cache, new_inv
            )

    x_last = rms_norm(x_cur[:, -1, :], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x_last @ head.astype(x_last.dtype)).astype(jnp.float32)
    return logits, x_last, ModelCaches(groups=tuple(seg_caches), shared=shared_cache)


def prefill_lane(params, cfg: ModelConfig, inputs: dict, caches: ModelCaches, lane, *, spec: CacheSpec, chunk: int = 1024):
    """Prefill ONE lane of a batched cache, in place.

    Runs the prompt through a fresh single-lane cache (allocated inside the
    trace — fused away by XLA) and scatters the result into ``caches`` at
    batch index ``lane`` (a traced scalar: one compilation serves all lanes).
    Jit this with the batched caches donated and admission costs one dispatch
    and zero extra cache copies — the engine's continuous-batching admit path.
    Returns (logits_last [1,V], hidden_last [1,d], updated caches).
    """
    lane_caches = init_caches(cfg, 1, spec)
    logits, hidden, lane_caches = prefill(params, cfg, inputs, lane_caches, spec=spec, chunk=chunk)
    new_caches = jax.tree.map(
        lambda full, part: jax.lax.dynamic_update_slice_in_dim(
            full, part.astype(full.dtype), lane, axis=1
        ),
        caches,
        lane_caches,
    )
    return logits, hidden, new_caches


def _last_query(block_params, cfg: ModelConfig, x_in, positions, lora_idx=None):
    """Recompute the last position's rotated query [B,H,D] (cheap: one token).

    block_params: a block dict with "ln1" + "attn"; x_in: the block's input.
    """
    h = rms_norm(x_in[:, -1:, :], block_params["ln1"], cfg.norm_eps)
    q, _, _ = attention._project_qkv(block_params["attn"], cfg, h, lora_idx)
    if cfg.rope_kind == "mrope":
        q = attention._rotate(cfg, q, positions[:, :, -1:])
    else:
        q = attention._rotate(cfg, q, positions[:, -1:])
    return q[:, 0]


def _mamba2_fwd_state(p_layer, cfg: ModelConfig, x):
    """Mamba2 layer forward that also returns the terminal decode state."""
    h = rms_norm(x, p_layer["ln"], cfg.norm_eps)
    y, state = mamba2.mamba2_forward(p_layer["mixer"], cfg, h, return_state=True)
    return _radd(x, y), state


def _rwkv6_fwd_state(p_layer, cfg: ModelConfig, x):
    h = rms_norm(x, p_layer["ln1"], cfg.norm_eps)
    y, (shift_tm, wkv) = rwkv6.rwkv6_tmix_forward(p_layer["tmix"], cfg, h)
    x = _radd(x, y)
    h2 = rms_norm(x, p_layer["ln2"], cfg.norm_eps)
    y2, shift_cm = rwkv6.rwkv6_cmix_forward(p_layer["cmix"], cfg, h2)
    state = cache_lib.RWKV6State(shift_tm=shift_tm, shift_cm=shift_cm, wkv=wkv)
    return _radd(x, y2), state


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def decode_step(params, cfg: ModelConfig, inputs: dict, caches: ModelCaches, *, spec: CacheSpec):
    """One-token decode. inputs: {"tokens": [B] int32} or {"embeds": [B,d]},
    plus "positions": [B] (or [B,3]). Returns (logits [B,V], hidden [B,d], caches').
    """
    params = cast_params(params, cfg)
    assert not cfg.is_encoder_only
    if "embeds" in inputs:
        x = inputs["embeds"][:, None, :].astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = params["embed"][inputs["tokens"]][:, None, :].astype(jnp.dtype(cfg.compute_dtype))
    B = x.shape[0]
    positions = inputs["positions"]

    groups = cfg.layer_groups()

    def block_body(grp):
        def body(carry, xs):
            p_layer, cache = xs
            x_c = carry
            if grp.kind == "attn":
                h = rms_norm(x_c, p_layer["ln1"], cfg.norm_eps)
                if cfg.attn_kind == "mla":
                    y, new_cache, _ = mla.mla_decode(p_layer["attn"], cfg, h, cache, positions)
                elif spec.kind == "synapse":
                    y, new_cache, _ = synapse_lib.synapse_decode(p_layer["attn"], cfg, h, cache, positions, spec.policy)
                else:
                    y, new_cache, _ = attention.attention_decode_full(p_layer["attn"], cfg, h, cache, positions)
                x_c = _radd(x_c, y)
                h = rms_norm(x_c, p_layer["ln2"], cfg.norm_eps)
                if grp.mlp == "moe":
                    y, _ = moe.moe_forward(p_layer["mlp"], cfg, h)
                else:
                    y = swiglu(p_layer["mlp"], h)
                return _radd(x_c, y), new_cache
            if grp.kind == "mamba2":
                h = rms_norm(x_c, p_layer["ln"], cfg.norm_eps)
                y, new_cache = mamba2.mamba2_decode(p_layer["mixer"], cfg, h, cache)
                return _radd(x_c, y), new_cache
            # rwkv6
            h = rms_norm(x_c, p_layer["ln1"], cfg.norm_eps)
            y, new_cache = rwkv6.rwkv6_tmix_decode(p_layer["tmix"], cfg, h, cache)
            x_c = _radd(x_c, y)
            h = rms_norm(x_c, p_layer["ln2"], cfg.norm_eps)
            y, new_cache = rwkv6.rwkv6_cmix_decode(p_layer["cmix"], cfg, h, new_cache)
            return _radd(x_c, y), new_cache
        return body

    seg_caches = list(caches.groups)
    shared_cache = caches.shared
    x_cur = x
    for seg in build_segments(cfg):
        grp = groups[seg.group]
        p_seg = _slice_group(params["groups"][seg.group], seg.start, seg.count)
        c_seg = _slice_group(seg_caches[seg.group], seg.start, seg.count)
        x_cur, new_c = _scan_stack(block_body(grp), x_cur, (p_seg, c_seg), seg.count, cfg.scan_layers)
        seg_caches[seg.group] = jax.tree.map(
            lambda full, part: jax.lax.dynamic_update_slice_in_dim(full, part, seg.start, axis=0),
            seg_caches[seg.group],
            new_c,
        )
        if seg.shared_after >= 0:
            inv_cache = jax.tree.map(lambda a: a[seg.shared_after], shared_cache)
            h = rms_norm(x_cur, params["shared_attn"]["ln1"], cfg.norm_eps)
            if spec.kind == "synapse":
                y, new_inv, _ = synapse_lib.synapse_decode(params["shared_attn"]["attn"], cfg, h, inv_cache, positions, spec.policy)
            else:
                y, new_inv, _ = attention.attention_decode_full(params["shared_attn"]["attn"], cfg, h, inv_cache, positions)
            x_cur = _radd(x_cur, y)
            h = rms_norm(x_cur, params["shared_attn"]["ln2"], cfg.norm_eps)
            x_cur = _radd(x_cur, swiglu(params["shared_attn"]["mlp"], h))
            shared_cache = jax.tree.map(lambda full, part: full.at[seg.shared_after].set(part), shared_cache, new_inv)

    hidden = rms_norm(x_cur[:, 0, :], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (hidden @ head.astype(hidden.dtype)).astype(jnp.float32)
    return logits, hidden, ModelCaches(groups=tuple(seg_caches), shared=shared_cache)
