"""Model configuration for the unified decoder family.

One ModelConfig describes every assigned architecture. The layer stack is
derived as a list of homogeneous ``LayerGroup``s so the forward pass can
``lax.scan`` over stacked per-layer parameters (compile-time discipline for
80-layer models on 512 devices — see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

BlockKind = Literal["attn", "mamba2", "rwkv6"]
MlpKind = Literal["dense", "moe", "rwkv_cmix", "none"]
AttnKind = Literal["gqa", "mla", "none"]
RopeKind = Literal["rope", "mrope", "none"]


@dataclass(frozen=True)
class LayerGroup:
    """A contiguous run of identical layers, scanned as one lax.scan."""

    kind: BlockKind
    mlp: MlpKind
    count: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    # ---- attention features ----
    attn_kind: AttnKind = "gqa"
    causal: bool = True
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_kind: RopeKind = "rope"
    rope_theta: float = 1_000_000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t, h, w (per half-dim)
    # ---- MLA (deepseek-v2) ----
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # ---- MoE ----
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    first_k_dense: int = 0           # leading dense layers (deepseek-v2: 1)
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    dense_d_ff: int = 0              # d_ff for the leading dense layers / shared experts scale
    # ---- SSM (mamba2) ----
    ssm_state_size: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # ---- RWKV6 ----
    rwkv_head_size: int = 64
    rwkv_lora_decay: int = 64
    rwkv_lora_mix: int = 32
    # ---- hybrid (zamba2): shared attention block every N ssm layers ----
    shared_attn_every: int = 0
    shared_attn_lora_rank: int = 0   # per-invocation LoRA on shared qkv
    # ---- misc ----
    block_kind: BlockKind = "attn"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_inputs: bool = True        # False for stubbed modality frontends (vlm/audio)
    max_position: int = 1 << 20
    # Runtime knobs (not architecture): may be overridden per-run.
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save MXU outputs, skip recompute)
    moe_dispatch: str = "per_lane"  # per_lane (shardable sort) | global
    scan_layers: bool = True  # False: python-unrolled stacks (roofline probes)

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_kv_heads == 0 or self.n_heads % max(self.n_kv_heads, 1) == 0

    # ------------------------------------------------------------------
    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.block_kind in ("mamba2", "rwkv6") and self.shared_attn_every == 0

    @property
    def ssm_d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def n_shared_attn_invocations(self) -> int:
        if self.shared_attn_every <= 0:
            return 0
        return self.n_layers // self.shared_attn_every

    # ------------------------------------------------------------------
    def layer_groups(self) -> list[LayerGroup]:
        """Homogeneous scan groups, in depth order."""
        if self.block_kind == "rwkv6":
            return [LayerGroup("rwkv6", "rwkv_cmix", self.n_layers)]
        if self.block_kind == "mamba2":
            return [LayerGroup("mamba2", "none", self.n_layers)]
        mlp: MlpKind = "moe" if self.is_moe else "dense"
        groups: list[LayerGroup] = []
        if self.is_moe and self.first_k_dense > 0:
            groups.append(LayerGroup("attn", "dense", self.first_k_dense))
        groups.append(
            LayerGroup("attn", mlp, self.n_layers - (self.first_k_dense if self.is_moe else 0))
        )
        return groups

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (for rooflines and Table-1 style math)."""
        D, V = self.d_model, self.vocab_size
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += D * V  # lm head
        per_layer_attn = 0
        if self.block_kind == "attn":
            if self.attn_kind == "mla":
                qdim = self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                if self.q_lora_rank:
                    per_layer_attn += D * self.q_lora_rank + self.q_lora_rank * qdim
                else:
                    per_layer_attn += D * qdim
                per_layer_attn += D * (self.kv_lora_rank + self.qk_rope_head_dim)
                per_layer_attn += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.v_head_dim
                )
                per_layer_attn += self.n_heads * self.v_head_dim * D
            else:
                q = D * self.n_heads * self.d_head
                kv = 2 * D * self.n_kv_heads * self.d_head
                o = self.n_heads * self.d_head * D
                per_layer_attn = q + kv + o
        total_layers = 0
        for g in self.layer_groups():
            if g.kind == "attn":
                per_mlp = (
                    3 * D * (self.dense_d_ff or self.d_ff)
                    if g.mlp == "dense" and self.is_moe
                    else 3 * D * self.d_ff
                )
                if g.mlp == "moe":
                    per_mlp = self.n_experts * 3 * D * self.d_ff
                    per_mlp += self.n_experts * D  # router
                    per_mlp += self.n_shared_experts * 3 * D * (self.dense_d_ff or self.d_ff)
                total_layers += g.count * (per_layer_attn + per_mlp + 2 * D)
            elif g.kind == "mamba2":
                di, ds, nh = self.ssm_d_inner, self.ssm_state_size, self.ssm_n_heads
                inp = D * (2 * di + 2 * ds + nh)
                conv = (di + 2 * ds) * self.ssm_conv_width
                out = di * D
                total_layers += g.count * (inp + conv + out + nh + nh + di + D)
            elif g.kind == "rwkv6":
                hs = self.rwkv_head_size
                tm = 4 * D * D + D * hs  # r,k,v,o(g) projections + per-head extras
                tm += 5 * (self.rwkv_lora_mix * D * 2) + self.rwkv_lora_decay * D * 2
                cm = 2 * D * self.d_ff
                total_layers += g.count * (tm + cm + 2 * D)
        total += total_layers
        if self.shared_attn_every > 0:
            q = D * self.n_heads * self.d_head
            kv = 2 * D * self.n_kv_heads * self.d_head
            o = self.n_heads * self.d_head * D
            mlp = 3 * D * self.d_ff
            total += q + kv + o + mlp + 2 * D
            r = self.shared_attn_lora_rank
            if r:
                qkv_out = (self.n_heads + 2 * self.n_kv_heads) * self.d_head
                total += self.n_shared_attn_invocations * (D * r + r * qkv_out)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        moe_layers = self.n_layers - self.first_k_dense
        skipped = moe_layers * (self.n_experts - self.experts_per_token) * 3 * self.d_model * self.d_ff
        return full - skipped

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        small: dict = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            max_position=65536,
        )
        n_heads = max(2, min(self.n_heads, 4))
        small["n_heads"] = n_heads
        if self.n_kv_heads:
            small["n_kv_heads"] = n_heads if self.n_kv_heads == self.n_heads else max(1, n_heads // 2)
        small["d_head"] = small["d_model"] // n_heads
        if self.is_moe:
            small.update(
                n_experts=4,
                experts_per_token=2,
                n_shared_experts=min(self.n_shared_experts, 1),
                first_k_dense=min(self.first_k_dense, 1),
                dense_d_ff=min(self.dense_d_ff, 512) if self.dense_d_ff else 0,
            )
        if self.attn_kind == "mla":
            small.update(
                kv_lora_rank=64,
                q_lora_rank=32 if self.q_lora_rank else 0,
                qk_nope_head_dim=32,
                qk_rope_head_dim=16,
                v_head_dim=32,
                d_head=0,
            )
        if self.block_kind == "mamba2":
            small.update(ssm_state_size=min(self.ssm_state_size, 16), ssm_head_dim=32, ssm_chunk=32)
        if self.block_kind == "rwkv6":
            small.update(rwkv_head_size=32, rwkv_lora_decay=16, rwkv_lora_mix=8)
        if self.rope_kind == "mrope":
            half = (small["d_model"] // n_heads) // 2
            t = half // 4
            h = (half - t) // 2
            small["mrope_sections"] = (t, h, half - t - h)
        if self.shared_attn_every:
            small.update(shared_attn_every=1, shared_attn_lora_rank=min(self.shared_attn_lora_rank, 8))
        small["name"] = self.name + "-smoke"
        small.update(overrides)
        return dataclasses.replace(self, **small)
