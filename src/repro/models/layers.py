"""Primitive layers: norms, projections, rotary embeddings, MLPs.

Pure functions over param pytrees. Params are plain nested dicts of
jnp arrays; initializers take an explicit PRNG key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rms_norm_init(d: int, dtype):
    return jnp.ones((d,), dtype=dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float):
    """Inverse frequencies [d_head//2]."""
    return 1.0 / (theta ** (np.arange(0, d_head, 2).astype(np.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d, theta))
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, D/2]
    ang = ang[..., None, :]  # broadcast over heads: [..., S, 1, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections: tuple[int, int, int]):
    """Multimodal RoPE (Qwen2-VL §3): positions [..., 3, S] for (t, h, w).

    The head dim's frequency bands are partitioned into `sections` (halved
    dims: sum(sections) == d_head // 2); each band rotates by its own
    positional axis. For pure-text input all three axes carry the same index
    and this reduces to standard RoPE.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = jnp.asarray(rope_freqs(d, theta))  # [D/2]
    # ang[axis]: [..., S, D/2]
    ang_all = positions[..., :, :, None].astype(jnp.float32) * inv  # [..., 3, S, D/2]
    sel = np.zeros((3, d // 2), np.float32)
    start = 0
    for axis, sec in enumerate(sections):
        sel[axis, start : start + sec] = 1.0
        start += sec
    ang = jnp.einsum("...tsd,td->...sd", ang_all, jnp.asarray(sel))
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def swiglu_init(key, d_model: int, d_ff: int, dtype):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "gate": dense_init(kg, d_model, d_ff, dtype),
        "up": dense_init(ku, d_model, d_ff, dtype),
        "down": dense_init(kd, d_ff, d_model, dtype),
    }


def swiglu(params, x):
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    return h @ params["down"]
