"""RWKV6 "Finch" (arXiv:2404.05892): data-dependent decay linear attention.

Time-mix recurrence per head (k-dim x v-dim matrix state S):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
with data-dependent per-channel decay w_t = exp(-exp(w0 + lora_w(x'_t))) and
data-dependent token-shift interpolation (ddlerp) via low-rank adapters.

Training runs the recurrence with lax.scan over time (fp32 state); decode is
the O(1) single-step update. Attention-free: the Warp-Cortex synapse is
inapplicable (state is already O(1)); referential injection is re-expressed
as a state blend (core/injection.py) — see DESIGN.md §4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import cache as cache_lib
from repro.models.config import ModelConfig
from repro.models.layers import dense_init

MIX_NAMES = ("w", "k", "v", "r", "g")


def rwkv6_tmix_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 12)
    d, h, hs = cfg.d_model, cfg.rwkv_n_heads, cfg.rwkv_head_size
    lm, ld = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
    p = {
        "mu_x": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dtype),
        "mu": (jax.random.uniform(ks[1], (5, d)) * 0.5).astype(dtype),
        "mix_a": (jax.random.normal(ks[2], (5, d, lm)) * 0.01).astype(dtype),
        "mix_b": jnp.zeros((5, lm, d), dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "decay_a": (jax.random.normal(ks[3], (d, ld)) * 0.01).astype(dtype),
        "decay_b": jnp.zeros((ld, d), dtype),
        "u": (jax.random.normal(ks[4], (h, hs)) * 0.1).astype(jnp.float32),
        "wr": dense_init(ks[5], d, d, dtype),
        "wk": dense_init(ks[6], d, d, dtype),
        "wv": dense_init(ks[7], d, d, dtype),
        "wg": dense_init(ks[8], d, d, dtype),
        "wo": dense_init(ks[9], d, d, dtype),
        "ln_x": jnp.ones((d,), dtype),  # per-head group norm scale
    }
    return p


def rwkv6_cmix_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    d, dff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dtype),
        "mu_r": (jax.random.uniform(ks[1], (d,)) * 0.5).astype(dtype),
        "wk": dense_init(ks[2], d, dff, dtype),
        "wv": dense_init(ks[3], dff, d, dtype),
        "wr": dense_init(ks[0], d, d, dtype),
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift for the 5 mix targets. -> [5, B, S, d]."""
    xx = x_prev - x
    base = x + xx * p["mu_x"]
    t = jnp.tanh(jnp.einsum("bsd,ndr->nbsr", base, p["mix_a"]))
    lora = jnp.einsum("nbsr,nrd->nbsd", t, p["mix_b"])
    mix = p["mu"][:, None, None, :] + lora  # [5,B,S,d]
    return x[None] + xx[None] * mix


def _group_norm(x, weight, h, eps=1e-5):
    """Per-head layer norm over head_size. x: [..., d] viewed as [..., h, hs]."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], h, shp[-1] // h).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(shp) * weight.astype(jnp.float32)).astype(x.dtype)


def _tmix_projections(p, cfg: ModelConfig, x, x_prev):
    """Shared by forward and decode. x, x_prev: [B,S,d]."""
    B, S, d = x.shape
    h, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
    mixed = _ddlerp(p, x, x_prev)
    xw, xk, xv, xr, xg = mixed[0], mixed[1], mixed[2], mixed[3], mixed[4]
    r = (xr @ p["wr"]).reshape(B, S, h, hs)
    k = (xk @ p["wk"]).reshape(B, S, h, hs)
    v = (xv @ p["wv"]).reshape(B, S, h, hs)
    g = jax.nn.silu(xg @ p["wg"])
    logw = p["w0"] + jnp.einsum("bsr,rd->bsd", jnp.tanh(xw @ p["decay_a"]), p["decay_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw)).reshape(B, S, h, hs)  # decay in (0,1)
    return r, k, v, g, w


def rwkv6_tmix_forward(p, cfg: ModelConfig, x, shift_state=None, wkv_state=None):
    """Full-sequence time-mix. x: [B,S,d]. Returns (y, new_states)."""
    B, S, d = x.shape
    h, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
    prev = jnp.zeros((B, 1, d), x.dtype) if shift_state is None else shift_state[:, None, :]
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)
    r, k, v, g, w = _tmix_projections(p, cfg, x, x_prev)

    S0 = jnp.zeros((B, h, hs, hs), jnp.float32) if wkv_state is None else wkv_state

    def step(S_prev, inp):
        rt, kt, vt, wt = inp  # [B,h,hs] each
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32))
        out = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32), S_prev + p["u"][None, :, :, None] * kv)
        S_new = S_prev * wt.astype(jnp.float32)[..., None] + kv
        return S_new, out

    xs = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), w.swapaxes(0, 1))
    S_fin, outs = jax.lax.scan(step, S0, xs)
    y = outs.swapaxes(0, 1).reshape(B, S, d)
    y = _group_norm(y, p["ln_x"], h)
    y = (y * g) @ p["wo"]
    return y, (x[:, -1, :], S_fin)


def rwkv6_tmix_decode(p, cfg: ModelConfig, x, state: cache_lib.RWKV6State):
    """Single token. x: [B,1,d]."""
    B, _, d = x.shape
    h, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
    x_prev = state.shift_tm[:, None, :]
    r, k, v, g, w = _tmix_projections(p, cfg, x, x_prev)
    rt, kt, vt, wt = r[:, 0], k[:, 0], v[:, 0], w[:, 0]
    kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32))
    out = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32), state.wkv + p["u"][None, :, :, None] * kv)
    S_new = state.wkv * wt.astype(jnp.float32)[..., None] + kv
    y = out.reshape(B, 1, d)
    y = _group_norm(y, p["ln_x"], h)
    y = (y * g) @ p["wo"]
    return y, dataclasses_replace_rwkv(state, shift_tm=x[:, 0, :], wkv=S_new)


def rwkv6_cmix_forward(p, cfg: ModelConfig, x, shift_state=None):
    B, S, d = x.shape
    prev = jnp.zeros((B, 1, d), x.dtype) if shift_state is None else shift_state[:, None, :]
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"]), x[:, -1, :]


def rwkv6_cmix_decode(p, cfg: ModelConfig, x, state: cache_lib.RWKV6State):
    y, last = rwkv6_cmix_forward(p, cfg, x, state.shift_cm)
    return y, dataclasses_replace_rwkv(state, shift_cm=last)


def dataclasses_replace_rwkv(state: cache_lib.RWKV6State, **kw) -> cache_lib.RWKV6State:
    import dataclasses

    return dataclasses.replace(state, **kw)
