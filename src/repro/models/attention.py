"""GQA attention: training/prefill (blocked) and decode (full & synapse caches).

Covers the assigned-architecture feature matrix:
  * grouped-query attention (any n_kv_heads | MHA when n_kv == n_heads)
  * qk_norm (qwen3), qkv bias (qwen1.5), RoPE / M-RoPE (qwen2-vl) / none (hubert)
  * bidirectional (encoder-only) and causal masks
  * per-invocation LoRA on the qkv projection (zamba2 shared block)

Decode paths return per-key attention mass (summed over heads) — the paper's
"Attention Score Summation" inverse-kernel-density term (§3.3) — so the
synapse policy can accumulate scores without a second pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache as cache_lib
from repro.models.config import ModelConfig
from repro.models.layers import apply_mrope, apply_rope, dense_init, rms_norm

NEG_INF = -1e30
SCORE_EMA = 0.99  # decay of the per-slot attention-mass accumulator


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, dtype, n_lora: int = 0):
    """n_lora > 0 adds stacked per-invocation LoRA adapters on fused qkv."""
    kq, kk, kv, ko, kl = jax.random.split(key, 5)
    h, hkv, d, dm = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    p = {
        "wq": dense_init(kq, dm, h * d, dtype),
        "wk": dense_init(kk, dm, hkv * d, dtype),
        "wv": dense_init(kv, dm, hkv * d, dtype),
        "wo": dense_init(ko, h * d, dm, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * d,), dtype)
        p["bk"] = jnp.zeros((hkv * d,), dtype)
        p["bv"] = jnp.zeros((hkv * d,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((d,), dtype)
        p["k_norm"] = jnp.ones((d,), dtype)
    if n_lora > 0:
        r = cfg.shared_attn_lora_rank
        out = (h + 2 * hkv) * d
        ka, kb = jax.random.split(kl)
        p["lora_a"] = (jax.random.normal(ka, (n_lora, dm, r)) / np.sqrt(dm)).astype(dtype)
        p["lora_b"] = jnp.zeros((n_lora, r, out), dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x, lora_idx=None):
    """x: [B, S, dm] -> q [B,S,H,D], k/v [B,S,Hkv,D]."""
    B, S, _ = x.shape
    h, hkv, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if lora_idx is not None and "lora_a" in p:
        a = p["lora_a"][lora_idx]
        b = p["lora_b"][lora_idx]
        delta = (x @ a) @ b  # [B, S, (h+2hkv)*d]
        dq, dk, dv = jnp.split(delta, [h * d, (h + hkv) * d], axis=-1)
        q, k, v = q + dq, k + dk, v + dv
    q = q.reshape(B, S, h, d)
    k = k.reshape(B, S, hkv, d)
    v = v.reshape(B, S, hkv, d)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rotate(cfg: ModelConfig, x, positions):
    if cfg.rope_kind == "none":
        return x
    if cfg.rope_kind == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------------------
# blocked full-sequence attention (training / prefill)
# ---------------------------------------------------------------------------
def blocked_attention(q, k, v, *, causal: bool, q_offset=0, chunk: int = 1024):
    """[B,S,H,D] x [B,T,Hkv,D] -> [B,S,H,D], chunked over queries.

    Peak memory is O(S_chunk * T) instead of O(S * T); on TPU the chunk loop
    lowers to a fori over MXU matmuls (flash-style but XLA-level).
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scale = 1.0 / np.sqrt(D)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    n_chunks = (S + pad) // chunk
    qg = qg.reshape(B, n_chunks, chunk, Hkv, G, D)
    kpos = jnp.arange(T)

    def one_chunk(c, qc):
        # qc: [B, chunk, Hkv, G, D]
        s = jnp.einsum("bqkgd,btkd->bkgqt", qc, k).astype(jnp.float32) * scale
        if causal:
            qpos = q_offset + c * chunk + jnp.arange(chunk)
            m = kpos[None, :] <= qpos[:, None]  # [chunk, T]
            s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgqt,btkd->bqkgd", p, v)

    out = jax.lax.map(
        jax.checkpoint(lambda args: one_chunk(*args)),  # flash-style: recompute scores in bwd
        (jnp.arange(n_chunks), qg.swapaxes(0, 1)),
    )
    out = out.swapaxes(0, 1).reshape(B, S + pad, H, D)
    return out[:, :S]


def attention_forward(params, cfg: ModelConfig, x, positions, *, lora_idx=None, chunk=1024):
    """Full-sequence forward. Returns (y, (k_rot, v)) for cache fill."""
    q, k, v = _project_qkv(params, cfg, x, lora_idx)
    q = _rotate(cfg, q, positions)
    k = _rotate(cfg, k, positions)
    out = blocked_attention(q, k, v, causal=cfg.causal, chunk=chunk)
    B, S = x.shape[:2]
    y = out.reshape(B, S, -1) @ params["wo"]
    return y, (k, v)


# ---------------------------------------------------------------------------
# decode: single-step attend over a key/value set
# ---------------------------------------------------------------------------
def decode_attend(q, keys, values, valid):
    """q: [B,H,D]; keys/values: [B,T,Hkv,D]; valid: [B,T] bool.

    Returns (out [B,H,D], key_mass [B,T] f32) where key_mass is attention
    probability summed over all query heads — the paper's density term.
    """
    B, H, D = q.shape
    Hkv = keys.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, keys).astype(jnp.float32) / np.sqrt(D)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(values.dtype), values)
    key_mass = p.sum(axis=(1, 2))  # [B, T]
    return out.reshape(B, H, D), key_mass


def attention_decode_full(params, cfg: ModelConfig, x, cache: cache_lib.FullCache, positions):
    """One-token decode against a FullCache.

    x: [B, 1, dm]; positions: [B] (rope index of the new token) or [B,3] mrope.
    """
    B = x.shape[0]
    pos_q = positions[..., None] if cfg.rope_kind != "mrope" else positions[..., None]
    q, k, v = _project_qkv(params, cfg, x)
    if cfg.rope_kind == "mrope":
        q = _rotate(cfg, q, positions[..., None])       # [B,3,1]
        k = _rotate(cfg, k, positions[..., None])
        pos_scalar = positions[:, 0]
    else:
        q = _rotate(cfg, q, pos_q)
        k = _rotate(cfg, k, pos_q)
        pos_scalar = positions
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]  # [B,H,D]/[B,Hkv,D]
    lane = jnp.arange(B)
    new_k = cache.k.at[lane, cache.length].set(k1)
    new_v = cache.v.at[lane, cache.length].set(v1)
    new_pos = cache.pos.at[lane, cache.length].set(pos_scalar)
    slots = jnp.arange(cache.capacity)
    valid = slots[None, :] <= cache.length[:, None]  # includes the token just written
    out, key_mass = decode_attend(q1, new_k, new_v, valid)
    y = out.reshape(B, -1) @ params["wo"]
    new_score = cache.score.at[lane, cache.length].set(0.0)
    new_score = new_score * SCORE_EMA + key_mass
    new_cache = cache_lib.FullCache(new_k, new_v, new_pos, new_score, cache.length + 1)
    return y[:, None, :], new_cache, key_mass
