"""Decode-time state pytrees.

All caches are fixed-shape (XLA static shapes): growth is expressed as a
write cursor, eviction as index arithmetic. Per-batch-lane lengths support
continuous batching (lanes at different positions).

Cache kinds
-----------
* FullCache      — standard KV cache [B, S, Hkv, D] with per-lane cursor.
* SynapseCache   — the paper's Topological Synapse as a *streaming* cache:
                   K landmark slots + W recent-window ring + J referential-
                   injection slots. O(K+W+J) per agent instead of O(L).
* MLACache       — DeepSeek-V2 latent cache (c_kv + shared rope key).
* Mamba2State    — conv tail + SSD state (O(1)).
* RWKV6State     — token-shift tails + wkv matrix state (O(1)).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _register(cls):
    fields = [f for f in cls.__dataclass_fields__]
    jax.tree_util.register_dataclass(cls, data_fields=fields, meta_fields=[])
    return cls


@_register
@dataclass
class FullCache:
    k: jax.Array       # [B, S, Hkv, D]
    v: jax.Array       # [B, S, Hkv, D]
    pos: jax.Array     # [B, S] int32 — rope position of each slot
    score: jax.Array   # [B, S] f32 — accumulated attention mass (density EMA)
    length: jax.Array  # [B] int32 — write cursor / valid prefix

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


@_register
@dataclass
class SynapseCache:
    # landmark region (the "Topological Synapse")
    lm_k: jax.Array      # [B, K, Hkv, D]
    lm_v: jax.Array      # [B, K, Hkv, D]
    lm_pos: jax.Array    # [B, K] int32
    lm_score: jax.Array  # [B, K] f32 — accumulated hybrid density-coverage score
    lm_count: jax.Array  # [B] int32 — populated landmark slots
    # recent window ring
    win_k: jax.Array     # [B, W, Hkv, D]
    win_v: jax.Array     # [B, W, Hkv, D]
    win_pos: jax.Array   # [B, W] int32
    win_score: jax.Array # [B, W] f32 — attention mass accumulated while resident
    # referential injection slots (paper §3.6)
    inj_k: jax.Array     # [B, J, Hkv, D]
    inj_v: jax.Array     # [B, J, Hkv, D]
    inj_pos: jax.Array   # [B, J] int32
    inj_count: jax.Array # [B] int32
    win_count: jax.Array # [B] int32 — tokens written into the ring (fill state)
    length: jax.Array    # [B] int32 — total stream tokens seen

    @property
    def n_landmarks(self) -> int:
        return self.lm_k.shape[1]

    @property
    def window(self) -> int:
        return self.win_k.shape[1]

    @property
    def n_inject(self) -> int:
        return self.inj_k.shape[1]


@_register
@dataclass
class MLACache:
    ckv: jax.Array     # [B, S, r] latent
    krope: jax.Array   # [B, S, d_rope] shared rope key
    score: jax.Array   # [B, S] f32 — accumulated attention mass (density EMA)
    length: jax.Array  # [B]

    @property
    def capacity(self) -> int:
        return self.ckv.shape[1]


@_register
@dataclass
class Mamba2State:
    conv: jax.Array  # [B, conv_width-1, d_conv_ch] — conv input tail
    ssm: jax.Array   # [B, n_heads, d_head, d_state] f32


@_register
@dataclass
class RWKV6State:
    shift_tm: jax.Array  # [B, d_model] — previous token (time-mix)
    shift_cm: jax.Array  # [B, d_model] — previous token (channel-mix)
    wkv: jax.Array       # [B, H, head, head] f32 matrix state


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------
def init_full_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=None) -> FullCache:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hkv, d = cfg.n_kv_heads, cfg.d_head
    z = lambda *s: jnp.zeros(s, dtype)
    return FullCache(
        k=z(batch, capacity, hkv, d),
        v=z(batch, capacity, hkv, d),
        pos=jnp.zeros((batch, capacity), jnp.int32),
        score=jnp.zeros((batch, capacity), jnp.float32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def init_synapse_cache(
    cfg: ModelConfig,
    batch: int,
    n_landmarks: int,
    window: int,
    n_inject: int = 0,
    dtype=None,
) -> SynapseCache:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hkv, d = cfg.n_kv_heads, cfg.d_head
    z = lambda *s: jnp.zeros(s, dtype)
    zi = lambda *s: jnp.zeros(s, jnp.int32)
    zf = lambda *s: jnp.zeros(s, jnp.float32)
    return SynapseCache(
        lm_k=z(batch, n_landmarks, hkv, d),
        lm_v=z(batch, n_landmarks, hkv, d),
        lm_pos=zi(batch, n_landmarks),
        lm_score=jnp.full((batch, n_landmarks), -jnp.inf, jnp.float32),
        lm_count=zi(batch),
        win_k=z(batch, window, hkv, d),
        win_v=z(batch, window, hkv, d),
        win_pos=zi(batch, window),
        win_score=zf(batch, window),
        inj_k=z(batch, max(n_inject, 1), hkv, d),
        inj_v=z(batch, max(n_inject, 1), hkv, d),
        inj_pos=zi(batch, max(n_inject, 1)),
        inj_count=zi(batch),
        win_count=zi(batch),
        length=zi(batch),
    )


def init_mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=None) -> MLACache:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    return MLACache(
        ckv=jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
        krope=jnp.zeros((batch, capacity, cfg.qk_rope_head_dim), dtype),
        score=jnp.zeros((batch, capacity), jnp.float32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype=None) -> Mamba2State:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    d_conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_state_size
    return Mamba2State(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, d_conv_ch), dtype),
        ssm=jnp.zeros((batch, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state_size), jnp.float32),
    )


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype=None) -> RWKV6State:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    h, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
    return RWKV6State(
        shift_tm=jnp.zeros((batch, cfg.d_model), dtype),
        shift_cm=jnp.zeros((batch, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, h, hs, hs), jnp.float32),
    )


def cache_bytes(cache) -> int:
    """Exact live bytes of a cache pytree (the paper's 'VRAM per agent')."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(cache))
