"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

The KV cache stores only the latent ``c_kv`` (kv_lora_rank) plus one shared
rope key per token — this is itself a KV compression, and the Warp-Cortex
synapse composes with it: landmark selection runs directly on the latent
point cloud (see DESIGN.md §4).

Decode uses the *absorbed* form: W_uk is folded into the query and W_uv into
the output so attention works in latent space — O(r) per cached token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache as cache_lib
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


def mla_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    dm, h = cfg.d_model, cfg.n_heads
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    p = {}
    qdim = h * (dn + dr)
    if cfg.q_lora_rank:
        p["wdq"] = dense_init(ks[0], dm, cfg.q_lora_rank, dtype)
        p["q_lora_norm"] = jnp.ones((cfg.q_lora_rank,), dtype)
        p["wuq"] = dense_init(ks[1], cfg.q_lora_rank, qdim, dtype)
    else:
        p["wq"] = dense_init(ks[1], dm, qdim, dtype)
    p["wdkv"] = dense_init(ks[2], dm, r + dr, dtype)
    p["kv_norm"] = jnp.ones((r,), dtype)
    p["wuk"] = dense_init(ks[3], r, h * dn, dtype)
    p["wuv"] = dense_init(ks[4], r, h * dv, dtype)
    p["wo"] = dense_init(ks[5], h * dv, dm, dtype)
    return p


def _queries(p, cfg: ModelConfig, x):
    B, S, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = rms_norm(x @ p["wdq"], p["q_lora_norm"], cfg.norm_eps)
        q = cq @ p["wuq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, S, h, dn + dr)
    return q[..., :dn], q[..., dn:]  # q_nope [B,S,h,dn], q_rope [B,S,h,dr]


def _latents(p, cfg: ModelConfig, x, positions):
    ckv_full = x @ p["wdkv"]
    ckv, krope = ckv_full[..., : cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank :]
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    krope = apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, krope  # [B,S,r], [B,S,dr]


def mla_forward(p, cfg: ModelConfig, x, positions, *, chunk: int = 1024):
    """Training/prefill: materialized keys/values, blocked over queries."""
    B, S, _ = x.shape
    h, dn, dv, dr = cfg.n_heads, cfg.qk_nope_head_dim, cfg.v_head_dim, cfg.qk_rope_head_dim
    qn, qr = _queries(p, cfg, x)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    ckv, krope = _latents(p, cfg, x, positions)
    kn = (ckv @ p["wuk"]).reshape(B, S, h, dn)
    v = (ckv @ p["wuv"]).reshape(B, S, h, dv)
    scale = 1.0 / np.sqrt(dn + dr)

    chunk = min(chunk, S)
    pad = (-S) % chunk
    qn_p = jnp.pad(qn, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else qn
    qr_p = jnp.pad(qr, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else qr
    n_chunks = (S + pad) // chunk
    qn_c = qn_p.reshape(B, n_chunks, chunk, h, dn).swapaxes(0, 1)
    qr_c = qr_p.reshape(B, n_chunks, chunk, h, dr).swapaxes(0, 1)
    kpos = jnp.arange(S)

    def one_chunk(args):
        c, qnc, qrc = args
        s = jnp.einsum("bqhd,bthd->bhqt", qnc, kn) + jnp.einsum("bqhd,btd->bhqt", qrc, krope)
        s = s.astype(jnp.float32) * scale
        qpos = c * chunk + jnp.arange(chunk)
        s = jnp.where((kpos[None, :] <= qpos[:, None])[None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqt,bthd->bqhd", pr, v)

    out = jax.lax.map(jax.checkpoint(one_chunk), (jnp.arange(n_chunks), qn_c, qr_c))
    out = out.swapaxes(0, 1).reshape(B, S + pad, h, dv)[:, :S]
    y = out.reshape(B, S, h * dv) @ p["wo"]
    return y, (ckv, krope)


def mla_decode(p, cfg: ModelConfig, x, cache: cache_lib.MLACache, positions):
    """Absorbed-form single-token decode. x: [B,1,dm], positions: [B]."""
    B = x.shape[0]
    h, dn, dv, dr, r = (
        cfg.n_heads,
        cfg.qk_nope_head_dim,
        cfg.v_head_dim,
        cfg.qk_rope_head_dim,
        cfg.kv_lora_rank,
    )
    qn, qr = _queries(p, cfg, x)
    qr = apply_rope(qr, positions[:, None], cfg.rope_theta)
    ckv_new, krope_new = _latents(p, cfg, x, positions[:, None])
    lane = jnp.arange(B)
    ckv_c = cache.ckv.at[lane, cache.length].set(ckv_new[:, 0])
    krope_c = cache.krope.at[lane, cache.length].set(krope_new[:, 0])
    # absorb W_uk into q:  q_lat[b,h,r] = sum_dn qn[b,h,dn] * Wuk[r, h, dn]
    wuk = p["wuk"].reshape(r, h, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", qn[:, 0], wuk)
    s = jnp.einsum("bhr,btr->bht", q_lat, ckv_c) + jnp.einsum(
        "bhd,btd->bht", qr[:, 0], krope_c
    )
    s = s.astype(jnp.float32) / np.sqrt(dn + dr)
    slots = jnp.arange(cache.capacity)
    valid = slots[None, :] <= cache.length[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    key_mass = pr.sum(axis=1)  # [B, T] — density term for the synapse
    out_lat = jnp.einsum("bht,btr->bhr", pr.astype(ckv_c.dtype), ckv_c)
    wuv = p["wuv"].reshape(r, h, dv)
    out = jnp.einsum("bhr,rhd->bhd", out_lat, wuv)
    y = out.reshape(B, h * dv) @ p["wo"]
    new_score = cache.score.at[lane, cache.length].set(0.0) * 0.99 + key_mass
    new_cache = cache_lib.MLACache(ckv_c, krope_c, new_score, cache.length + 1)
    return y[:, None, :], new_cache, key_mass
