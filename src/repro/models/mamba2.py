"""Mamba2 (SSD) mixer — chunked parallel training form + O(1) decode step.

Used by zamba2 (hybrid). The chunked state-space-dual algorithm expresses the
selective scan as blocked matmuls (TPU/MXU-friendly): within-chunk quadratic
attention-like term + cross-chunk recurrence over chunk states.

Recurrence (per head h, scalar decay):
    H_t = a_t * H_{t-1} + (dt_t x_t) ⊗ B_t        a_t = exp(dt_t * A_h)
    y_t = C_t · H_t + D_h * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import cache as cache_lib
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm

NEG_INF = -1e30


def mamba2_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    dm, di, ds, nh = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state_size, cfg.ssm_n_heads
    d_conv_ch = di + 2 * ds
    return {
        # in_proj -> [z (di), xBC (di + 2ds), dt (nh)]
        "w_in": dense_init(ks[0], dm, 2 * di + 2 * ds + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, d_conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_conv_ch,), dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),       # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[2], di, dm, dtype),
    }


def _split_in(cfg: ModelConfig, zxbcdt):
    di, ds, nh = cfg.ssm_d_inner, cfg.ssm_state_size, cfg.ssm_n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * ds]
    dt = zxbcdt[..., 2 * di + 2 * ds :]
    return z, xbc, dt


def mamba2_forward(p, cfg: ModelConfig, x, return_state: bool = False):
    """Full-sequence chunked SSD. x: [B,S,dm] -> y [B,S,dm] (+ terminal state)."""
    B, S, _ = x.shape
    di, ds, nh, dh = cfg.ssm_d_inner, cfg.ssm_state_size, cfg.ssm_n_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q

    zxbcdt = x @ p["w_in"]
    z, xbc, dt = _split_in(cfg, zxbcdt)
    # causal depthwise conv (width W)
    W = cfg.ssm_conv_width
    padded = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(
        padded[:, i : i + S, :] * p["conv_w"][i][None, None, :] for i in range(W)
    ) + p["conv_b"]
    xbc = jax.nn.silu(conv)
    xs = xbc[..., :di].reshape(B, S, nh, dh)
    Bm = xbc[..., di : di + ds]       # [B,S,ds]
    Cm = xbc[..., di + ds :]          # [B,S,ds]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,nh]
    A = -jnp.exp(p["a_log"])                                      # [nh]
    la = (dt * A).reshape(B, nC, Q, nh)                           # log decay per step
    cum = jnp.cumsum(la, axis=2)                                  # Λ_i
    X = (xs.astype(jnp.float32) * dt[..., None]).reshape(B, nC, Q, nh, dh)
    Bc = Bm.astype(jnp.float32).reshape(B, nC, Q, ds)
    Cc = Cm.astype(jnp.float32).reshape(B, nC, Q, ds)

    # ---- intra-chunk: Y[i] = Σ_{j<=i} exp(Λ_i-Λ_j) (C_i·B_j) X_j ----
    G = jnp.einsum("bcis,bcjs->bcij", Cc, Bc)  # [B,nC,Q,Q]
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # Λ_i - Λ_j: [B,nC,Q,Q,nh]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(mask[None, None, :, :, None], jnp.exp(dec), 0.0) * G[..., None]
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", M, X)

    # ---- chunk states: S_c = Σ_j exp(Λ_Q - Λ_j) B_j ⊗ X_j ----
    tail_dec = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nC,Q,nh]
    chunk_state = jnp.einsum("bcjh,bcjs,bcjhd->bchds", tail_dec, Bc, X)  # [B,nC,nh,dh,ds]
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,nC,nh] total decay of a chunk

    # ---- inter-chunk scan over chunk states ----
    def scan_fn(carry, inp):
        st, dcy = inp  # [B,nh,dh,ds], [B,nh]
        new = carry * dcy[:, :, None, None] + st
        return new, carry  # emit state *entering* the chunk

    init = jnp.zeros((B, nh, dh, ds), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init, (chunk_state.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    prev_states = prev_states.swapaxes(0, 1)  # [B,nC,nh,dh,ds] state at chunk start
    y_inter = jnp.einsum(
        "bcis,bcih,bchds->bcihd", Cc, jnp.exp(cum), prev_states
    )

    y = (y_intra + y_inter).reshape(B, S, nh, dh) + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    if not return_state:
        return out
    # terminal decode state: final SSD state + raw (pre-conv) input tail
    raw_xbc = _split_in(cfg, zxbcdt)[1]
    conv_tail = raw_xbc[:, S - (W - 1) :, :] if W > 1 else raw_xbc[:, :0, :]
    state = cache_lib.Mamba2State(conv=conv_tail.astype(x.dtype), ssm=final_state)
    return out, state


def mamba2_decode(p, cfg: ModelConfig, x, state: cache_lib.Mamba2State):
    """Single-token step. x: [B,1,dm]."""
    B = x.shape[0]
    di, ds, nh, dh = cfg.ssm_d_inner, cfg.ssm_state_size, cfg.ssm_n_heads, cfg.ssm_head_dim
    zxbcdt = x[:, 0] @ p["w_in"]
    z, xbc, dt = _split_in(cfg, zxbcdt[:, None, :])
    z, xbc, dt = z[:, 0], xbc[:, 0], dt[:, 0]
    # conv over [tail, new]
    W = cfg.ssm_conv_width
    window = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)  # [B, W, ch]
    conv = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc_a = jax.nn.silu(conv)
    xs = xbc_a[:, :di].reshape(B, nh, dh)
    Bm = xbc_a[:, di : di + ds]
    Cm = xbc_a[:, di + ds :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,nh]
    a = jnp.exp(dt * (-jnp.exp(p["a_log"])))  # [B,nh]
    X = xs.astype(jnp.float32) * dt[..., None]  # [B,nh,dh]
    new_ssm = state.ssm * a[:, :, None, None] + jnp.einsum("bhd,bs->bhds", X, Bm.astype(jnp.float32))
    y = jnp.einsum("bhds,bs->bhd", new_ssm, Cm.astype(jnp.float32)) + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(B, di)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = (y @ p["w_out"])[:, None, :]
    new_state = cache_lib.Mamba2State(conv=window[:, 1:, :].astype(state.conv.dtype), ssm=new_ssm)
    return out, new_state
