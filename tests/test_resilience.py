"""Fault tolerance of the tiered synapse memory (ISSUE 8).

The resilience contract, asserted here end to end:

* INTEGRITY — every cold read verifies the framed blob's checksum: a torn
  write, truncated file, or flipped bit surfaces as a typed
  `SnapshotLostError` (and the bad file moves to ``quarantine/``), never a
  raw codec exception or — worse — silently wrong cache bytes;
* RECOVERY — kill-and-restart: hibernate agents to cold, drop every piece
  of process state, `recover()` + `adopt_hibernated()` in a fresh engine,
  and the woken streams replay BITWISE vs an engine that never crashed
  (single-device and forced-8-device lane mesh);
* RETRY — transient I/O failures retry with bounded backoff and succeed;
  exhausted retries / deadlines / a dead prefetch worker fail the
  `WakeTicket` terminally (never hang a waiter) while the snapshot stays
  intact and re-wakeable; permanent loss marks the agent LOST, frees no
  lane, and the engine keeps ticking with every hot-path invariant (one
  sync per window, dispatch counts, zero-transfer overlap region) intact
  and untouched lanes bitwise identical to a fault-free run;
* CONCURRENCY — put/prefetch/drop/demote churn from many threads leaves no
  deadlock, no orphaned ``.tmp``/blob files, and exact tier accounting;
  the old get_host/drop race resolves to the key's current state instead
  of leaking ``FileNotFoundError``.
"""
import dataclasses
import os
import pickle
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_lane_mesh
from repro.memory import (
    ACTIVE,
    HIBERNATED,
    LOST,
    FaultInjector,
    SnapshotLostError,
    SynapseStore,
    WorkerDiedError,
)
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer

N_DEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

PROMPT_A = "calm text with no tags at all"
PROMPT_B = "another quiet prompt, still tagless"


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _engine(cfg, params, *, n_main=2, max_side=2, sync_every=4, mesh=None,
            store=None, wake_deadline_s=None):
    return CortexEngine(
        Prism(params, cfg), ByteTokenizer(cfg.vocab_size), n_main=n_main,
        max_side=max_side, main_capacity=128, side_max_steps=50,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=sync_every, mesh=mesh, store=store,
        wake_deadline_s=wake_deadline_s,
    )


def _tree_equal_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        assert x.tobytes() == y.tobytes()


def _snap(seed, kb=4):
    rng = np.random.default_rng(seed)
    return {
        "caches": rng.standard_normal(kb * 256).astype(np.float32),
        "tok": np.int32(seed),
        "pos": np.int64(seed * 10),
    }


def _cold_store(tmp_path, **kw):
    """warm_capacity_bytes=1 forces every put straight through to disk."""
    kw.setdefault("wake_backoff_s", 0.001)
    return SynapseStore(warm_capacity_bytes=1, cold_dir=str(tmp_path), **kw)


# ---------------------------------------------------------------------------
# framed blob format: integrity detection at the codec layer
# ---------------------------------------------------------------------------

def test_framed_roundtrip_bitwise_with_meta():
    tree = _snap(7)
    meta = pickle.dumps({"key": "x", "n": 3})
    blob = ckpt_io.dumps_framed(tree, meta=meta)
    hdr = ckpt_io.parse_frame_header(blob)
    assert hdr["codec"] in (ckpt_io.CODEC_ZLIB, ckpt_io.CODEC_ZSTD)
    got_meta, _, _ = ckpt_io.unframe(blob)
    assert got_meta == meta
    skel = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), tree
    )
    _tree_equal_bitwise(tree, ckpt_io.loads_framed(blob, skel, numpy=True))


def test_framed_catches_truncation_everywhere():
    blob = ckpt_io.dumps_framed(_snap(1), meta=b"m" * 17)
    for cut in (0, 3, ckpt_io.FRAME_HEADER_BYTES - 1, ckpt_io.FRAME_HEADER_BYTES,
                ckpt_io.FRAME_HEADER_BYTES + 5, len(blob) // 2, len(blob) - 1):
        with pytest.raises(ckpt_io.CorruptBlobError):
            ckpt_io.unframe(blob[:cut])
    with pytest.raises(ckpt_io.CorruptBlobError):  # oversize too
        ckpt_io.unframe(blob + b"x")


def test_framed_catches_every_single_bit_flip():
    """Flip one bit at EVERY byte offset: either verification raises
    CorruptBlobError, or (for bits the digest doesn't guard, e.g. inside
    the reserved header byte) decode still returns the original bytes —
    silent wrong data is never possible."""
    tree = _snap(2, kb=1)
    skel = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), tree
    )
    blob = ckpt_io.dumps_framed(tree, meta=b"bookkeeping")
    for i in range(len(blob)):
        bad = bytearray(blob)
        bad[i] ^= 0x01
        try:
            got = ckpt_io.loads_framed(bytes(bad), skel, numpy=True)
        except ckpt_io.CorruptBlobError:
            continue
        _tree_equal_bitwise(tree, got)  # e.g. the reserved byte: harmless


def test_read_frame_meta_cheap_and_checked(tmp_path):
    meta = pickle.dumps({"skeleton": "here"})
    blob = ckpt_io.dumps_framed(_snap(3), meta=meta)
    p = tmp_path / "x.blob"
    p.write_bytes(blob)
    assert ckpt_io.read_frame_meta(str(p)) == meta
    p.write_bytes(blob[: len(blob) - 10])  # truncated payload: size check fires
    with pytest.raises(ckpt_io.CorruptBlobError):
        ckpt_io.read_frame_meta(str(p))


# ---------------------------------------------------------------------------
# store: quarantine, retry/backoff, deadlines, worker supervision
# ---------------------------------------------------------------------------

def test_corrupt_cold_blob_quarantined(tmp_path):
    store = _cold_store(tmp_path, faults=FaultInjector().flip_write("a"))
    snap = _snap(1)
    store.put("a", snap)
    store.put("b", snap)  # written clean: must survive its neighbor's loss
    assert store.tier_of("a") == "cold"
    with pytest.raises(SnapshotLostError):
        store.get_host("a")
    assert store.tier_of("a") is None
    qdir = tmp_path / "quarantine"
    assert [p.name for p in qdir.iterdir()] and store.stats["quarantined"] == 1
    with pytest.raises(KeyError):  # follow-up access: plain miss, not loss
        store.get_host("a")
    _tree_equal_bitwise(snap, store.get_host("b"))


def test_torn_write_detected(tmp_path):
    store = _cold_store(tmp_path, faults=FaultInjector().torn_write("a", frac=0.6))
    store.put("a", _snap(1))
    with pytest.raises(SnapshotLostError):
        store.get_host("a")
    assert store.stats["lost"] == 1


def test_transient_read_failures_retry_through(tmp_path):
    store = _cold_store(tmp_path, faults=FaultInjector().fail_read("a", times=2))
    snap = _snap(4)
    store.put("a", snap)
    ticket = store.prefetch("a")
    _tree_equal_bitwise(snap, ticket.result(timeout=30))
    assert store.stats["wake_retries"] == 2
    assert store.stats["prefetch_errors"] == 0


def test_exhausted_retries_fail_ticket_terminally(tmp_path):
    store = _cold_store(tmp_path, faults=FaultInjector().fail_read("a", times=99))
    store.put("a", _snap(4))
    ticket = store.prefetch("a", retries=2)
    with pytest.raises(OSError):
        ticket.result(timeout=30)
    assert ticket.failed() and ticket.state == "failed"
    assert store.stats["prefetch_errors"] == 1
    assert store.stats["wake_retries"] == 2
    assert "a" in store  # the snapshot itself is intact: retryable later


def test_ticket_result_timeout_does_not_fail_ticket(tmp_path):
    """`result(timeout=)` expiry is the CALLER's timeout, not the ticket's:
    the promotion keeps going and can still succeed afterward."""
    store = _cold_store(
        tmp_path, faults=FaultInjector().slow_put("a", seconds=0.3)
    )
    snap = _snap(5)
    store.put("a", snap)
    ticket = store.prefetch("a", put_fn=lambda h: h)
    with pytest.raises(TimeoutError):
        ticket.result(timeout=0.01)
    assert not ticket.ready()  # still in flight, not failed
    _tree_equal_bitwise(snap, ticket.result(timeout=30))


def test_deadline_expires_blocked_promotion(tmp_path):
    """A worker stuck in put_fn cannot outlive the ticket deadline: the
    host expires the ticket (terminal TimeoutError) and the worker's late
    resolve loses the first-wins race — no crash, no hang."""
    release = threading.Event()
    store = _cold_store(
        tmp_path, faults=FaultInjector().block_put("a", release=release, timeout=30)
    )
    store.put("a", _snap(6))
    ticket = store.prefetch("a", put_fn=lambda h: h, deadline_s=0.05)
    deadline = time.monotonic() + 30
    while not ticket.ready() and time.monotonic() < deadline:
        ticket.expire()
        time.sleep(0.01)
    assert ticket.failed() and isinstance(ticket.error, TimeoutError)
    release.set()  # un-stick the worker; its resolve must be a no-op
    time.sleep(0.2)
    assert ticket.failed() and isinstance(ticket.error, TimeoutError)
    with pytest.raises(TimeoutError):
        ticket.result()
    # the worker survived (nothing raised through its loop): next wake works
    store.faults = None
    assert store.prefetch("a").result(timeout=30) is not None


@pytest.mark.filterwarnings("ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_worker_death_detected_and_healed(tmp_path):
    store = _cold_store(tmp_path, faults=FaultInjector().kill_worker_on_read("a"))
    snap = _snap(7)
    store.put("a", snap)
    ticket = store.prefetch("a")
    deadline = time.monotonic() + 30
    while store._worker.is_alive() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not store._worker.is_alive()
    assert store.heal_worker() == 1  # fails the orphaned in-flight ticket
    assert ticket.failed() and isinstance(ticket.error, WorkerDiedError)
    assert store.stats["worker_respawns"] == 1
    assert store.stats["prefetch_errors"] == 1
    # the respawned worker drains new tickets normally
    store.faults = None
    _tree_equal_bitwise(snap, store.prefetch("a").result(timeout=30))
    assert store.heal_worker() == 0  # healthy worker: supervision is a no-op


def test_get_host_drop_race_resolves_to_current_state(tmp_path):
    """Deterministic reproduction of the old race: the blob file vanishes
    between the index lookup and the read. A concurrent drop() must surface
    as a clean KeyError; a concurrent re-put() must return the NEW bytes;
    only a file missing with its index entry still live is a loss."""
    snap_old, snap_new = _snap(8), _snap(9)

    class RaceHook:
        def __init__(self, store, action):
            self.store, self.action, self.fired = store, action, False

        def on_cold_write(self, key, blob):
            return blob

        def on_put_fn(self, key):
            pass

        def on_cold_read(self, key, data):
            if not self.fired:
                self.fired = True
                self.action(self.store, key)  # mutate AFTER the file read...
                raise FileNotFoundError(key)  # ...and pretend the read lost
            return data

    # concurrent drop -> clean KeyError (the satellite's exact scenario)
    s1 = _cold_store(tmp_path / "d")
    s1.put("k", snap_old)
    s1.faults = RaceHook(s1, lambda st, k: st.drop(k))
    with pytest.raises(KeyError) as ei:
        s1.get_host("k")
    assert not isinstance(ei.value, SnapshotLostError)
    assert s1.stats["lost"] == 0 and s1.stats["quarantined"] == 0

    # concurrent re-put -> the new warm copy, not FileNotFoundError
    s2 = _cold_store(tmp_path / "r")
    s2.put("k", snap_old)
    s2.faults = RaceHook(s2, lambda st, k: st.put(k, snap_new))
    got = s2.get_host("k")
    _tree_equal_bitwise(
        {k: np.asarray(v) for k, v in snap_new.items()}, got
    )

    # file gone while still indexed -> permanent loss, index cleaned
    s3 = _cold_store(tmp_path / "l")
    s3.put("k", snap_old)
    os.remove(s3._cold_path("k"))
    with pytest.raises(SnapshotLostError):
        s3.get_host("k")
    assert "k" not in s3 and s3.stats["lost"] == 1


def test_concurrent_store_churn_no_orphans(tmp_path):
    """Satellite: hammer put/prefetch/drop/demote_lru from threads. No
    deadlock (bounded join), no orphaned .tmp/blob files, and the final
    report must account for exactly the keys that remain."""
    one = sum(np.asarray(x).nbytes for x in jax.tree.leaves(_snap(0)))
    store = SynapseStore(
        warm_capacity_bytes=3 * one, cold_dir=str(tmp_path), wake_backoff_s=0.001
    )
    snaps = {f"k{i}": _snap(i) for i in range(8)}
    stop = time.monotonic() + 3.0
    errors = []

    def churn(tid):
        rng = np.random.default_rng(tid)
        try:
            while time.monotonic() < stop:
                key = f"k{int(rng.integers(8))}"
                op = int(rng.integers(4))
                if op == 0:
                    store.put(key, snaps[key])
                elif op == 1:
                    try:
                        t = store.prefetch(key)
                        t.result(timeout=0.02)  # expiry path exercised too
                    except (KeyError, TimeoutError, OSError):
                        pass
                elif op == 2:
                    store.drop(key)
                else:
                    store.demote_lru()
        except Exception as e:  # anything else is a real bug
            errors.append(e)

    threads = [threading.Thread(target=churn, args=(t,)) for t in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "churn thread deadlocked"
    assert not errors, errors

    # drain the prefetch queue so no writer races the audit below
    store.heal_worker()
    for key in list(store.keys()):
        try:
            store.prefetch(key).result(timeout=30)
        except KeyError:
            pass
    # accounting: the report matches the index, the index matches the disk
    rep = store.report()
    keys = store.keys()
    assert rep["n_warm"] + rep["n_cold"] == len(keys)
    assert rep["warm_bytes"] == one * rep["n_warm"]
    on_disk = {p.name for p in tmp_path.iterdir()
               if p.name not in ("MANIFEST.pkl", "quarantine")}
    assert not {n for n in on_disk if n.endswith(".tmp")}, "orphaned tmp files"
    indexed = {os.path.basename(store._cold[k].path) for k in store._cold}
    assert on_disk == indexed, (on_disk, indexed)
    # every survivor still round-trips bitwise
    for key in keys:
        _tree_equal_bitwise(snaps[key], store.get_host(key))


# ---------------------------------------------------------------------------
# crash recovery: manifest + blob-embedded metadata
# ---------------------------------------------------------------------------

def test_recover_rebuilds_index_and_skeletons(tmp_path):
    store = _cold_store(tmp_path)
    snaps = {k: _snap(i) for i, k in enumerate(("alpha", "beta"))}
    for k, s in snaps.items():
        store.put(k, s, meta={"kind": "main", "tag": k})
    del store  # process death: only the directory survives

    fresh = SynapseStore(warm_capacity_bytes=1)
    report = fresh.recover(str(tmp_path))
    assert sorted(report["recovered"]) == ["alpha", "beta"]
    assert not report["quarantined"] and not report["lost"]
    assert fresh.stats["recovered"] == 2
    for k, s in snaps.items():
        assert fresh.tier_of(k) == "cold"
        assert fresh.meta_of(k) == {"kind": "main", "tag": k}
        _tree_equal_bitwise(s, fresh.get_host(k))


def test_recover_adopts_orphans_and_survives_bad_manifest(tmp_path):
    store = _cold_store(tmp_path)
    store.put("a", _snap(1), meta={"kind": "main"})
    store.put("b", _snap(2), meta={"kind": "main"})
    # crash before the manifest caught up: garbage manifest, blobs intact
    (tmp_path / "MANIFEST.pkl").write_bytes(b"not a pickle at all")
    fresh = SynapseStore(warm_capacity_bytes=1)
    report = fresh.recover(str(tmp_path))
    assert report["manifest_corrupt"]
    assert sorted(report["recovered"]) == ["a", "b"]
    assert sorted(report["orphans_adopted"]) == ["a", "b"]
    # and recover() rewrote a good manifest: a second restart is fast-path
    again = SynapseStore(warm_capacity_bytes=1)
    r2 = again.recover(str(tmp_path))
    assert sorted(r2["recovered"]) == ["a", "b"] and not r2["orphans_adopted"]


def test_recover_quarantines_corrupt_counts_missing(tmp_path):
    store = _cold_store(tmp_path)
    for k in ("good", "torn", "gone"):
        store.put(k, _snap(hash(k) % 100), meta={"kind": "main"})
    good_snap = store.get_host("good")
    # mangle the survivors: "torn" loses its payload tail, "gone" vanishes
    torn_path = store._cold_path("torn")
    blob = open(torn_path, "rb").read()
    open(torn_path, "wb").write(blob[: len(blob) // 2])
    os.remove(store._cold_path("gone"))
    del store

    fresh = SynapseStore(warm_capacity_bytes=1)
    report = fresh.recover(str(tmp_path))
    assert report["recovered"] == ["good"]
    assert len(report["quarantined"]) == 1 and report["lost"] == ["gone"]
    assert fresh.stats["quarantined"] == 1 and fresh.stats["lost"] == 1
    assert (tmp_path / "quarantine").exists()
    _tree_equal_bitwise(good_snap, fresh.get_host("good"))


# ---------------------------------------------------------------------------
# engine: kill-and-restart bitwise replay
# ---------------------------------------------------------------------------

def _run_kill_restart(cfg, params, mesh=None):
    # side lanes shard over the mesh: max_side must be a lane-axis multiple
    n_side = mesh.shape["lane"] if mesh is not None else 2
    # reference: same schedule, process never dies
    ref = _engine(cfg, params, mesh=mesh, max_side=n_side)
    ref.submit(PROMPT_A, lane=0, agent_id="alice")
    ref.submit(PROMPT_B, lane=1, agent_id="bob")
    ref.run(12)
    ref.hibernate("alice")
    ref.run(8)
    ref.wake("alice", wait=True)
    ref.run(12)
    ref_alice = next(m for m in ref.mains if m.agent_id == "alice")

    import tempfile

    cold_dir = tempfile.mkdtemp(prefix="resil_restart_")
    store = _cold_store(cold_dir)
    e1 = _engine(cfg, params, mesh=mesh, max_side=n_side, store=store)
    e1.submit(PROMPT_A, lane=0, agent_id="alice")
    e1.submit(PROMPT_B, lane=1, agent_id="bob")
    e1.run(12)
    e1.hibernate("alice")
    assert store.tier_of("alice") == "cold"
    del e1, store  # CRASH: every piece of process state is gone

    store2 = _cold_store(cold_dir)
    report = store2.recover(cold_dir)
    assert report["recovered"] == ["alice"]
    e2 = _engine(cfg, params, mesh=mesh, max_side=n_side, store=store2)
    adopted = e2.adopt_hibernated()
    assert adopted == ["alice"]
    rec = e2.registry.get("alice")
    assert rec.status == HIBERNATED
    assert e2.stats["recoveries"] == 1
    # bob never hibernated: his stream replays from scratch post-restart
    e2.submit(PROMPT_B, lane=1, agent_id="bob")
    e2.run(20)
    e2.wake("alice", wait=True)
    e2.run(12)
    alice2 = next(m for m in e2.mains if m.active and m.agent_id == "alice")
    # BITWISE: token ids, not just text
    assert alice2.tokens == ref_alice.tokens
    assert alice2.text == ref_alice.text
    # sampling params survived the crash too
    assert e2._main_sp[alice2.lane] == SamplingParams(greedy=True)


def test_kill_and_restart_replays_bitwise(setup):
    cfg, params = setup
    _run_kill_restart(cfg, params)


@needs_mesh
def test_kill_and_restart_replays_bitwise_on_mesh(setup):
    cfg, params = setup
    _run_kill_restart(cfg, params, mesh=make_lane_mesh(8))


def test_recovered_router_tail_still_matches_split_tag(setup):
    """A trigger tag split across the hibernate boundary must still fire
    after kill-and-restart: the router tail rides the blob metadata."""
    cfg, params = setup
    import tempfile

    cold_dir = tempfile.mkdtemp(prefix="resil_tail_")
    store = _cold_store(cold_dir)
    eng = _engine(cfg, params, store=store)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    # half a tag into the router, as a drain would leave it
    eng.router.feed("alice", "some text then [TA")
    eng.hibernate("alice")
    del eng, store

    store2 = _cold_store(cold_dir)
    store2.recover(cold_dir)
    e2 = _engine(cfg, params, store=store2)
    assert e2.adopt_hibernated() == ["alice"]
    trigs = e2.router.feed("alice", "SK: resume work] more text")
    assert [t.kind for t in trigs] == ["task"]
    assert trigs[0].payload == "resume work"


# ---------------------------------------------------------------------------
# engine: graceful degradation under injected faults
# ---------------------------------------------------------------------------

def test_permanent_loss_degrades_lost_engine_keeps_ticking(setup):
    """Corrupt blob at wake: the agent goes LOST, its would-be lane stays
    free, the OTHER lane's stream is bitwise identical to a fault-free
    engine, and the hot-path invariants (dispatch counts, one sync per
    window, zero transfers in the overlap region) hold throughout."""
    cfg, params = setup
    ref = _engine(cfg, params)
    ref.submit(PROMPT_A, lane=0, agent_id="alice")
    ref.submit(PROMPT_B, lane=1, agent_id="bob")
    ref.run(32)
    ref_bob = next(m for m in ref.mains if m.agent_id == "bob")

    import tempfile

    store = _cold_store(tempfile.mkdtemp(prefix="resil_lost_"),
                        faults=FaultInjector().flip_write("alice"))
    eng = _engine(cfg, params, store=store)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.submit(PROMPT_B, lane=1, agent_id="bob")
    eng.run(16)
    eng.hibernate("alice")
    eng.wake("alice")
    d0, s0, t0 = (eng.stats["tick_dispatches"], eng.stats["host_syncs"],
                  eng.stats["ticks"])
    eng.run(16)
    eng.flush_wakes()  # make the failing wake terminal before asserting
    # dispatch/sync accounting unchanged by the failing wake: one dispatch
    # and one host sync per sync_every window, exactly
    n_windows = (eng.stats["ticks"] - t0) / eng.sync_every
    assert eng.stats["tick_dispatches"] - d0 == n_windows
    assert eng.stats["host_syncs"] - s0 == n_windows
    assert eng.registry.get("alice").status == LOST
    assert eng.stats["lost_agents"] == 1 and store.stats["quarantined"] == 1
    assert eng.registry.counts()["lost"] == 1
    assert any(e["event"] == "lost" for e in eng.history)
    # bob, untouched: bitwise vs the fault-free reference at the same tick
    bob = next(m for m in eng.mains if m.agent_id == "bob")
    assert eng.stats["ticks"] == 32
    assert bob.tokens == ref_bob.tokens and bob.text == ref_bob.text
    # alice's lane is free again: a new agent can use it immediately
    eng.submit(PROMPT_A, lane=0, agent_id="carol")
    eng.run(4)
    assert eng.mains[0].agent_id == "carol" and eng.mains[0].active
    # waking a LOST agent is a clean error, not a crash
    with pytest.raises(ValueError):
        eng.wake("alice")


def test_transient_wake_failure_stays_hibernated_then_wakes(setup):
    cfg, params = setup
    import tempfile

    store = _cold_store(tempfile.mkdtemp(prefix="resil_transient_"),
                        faults=FaultInjector().fail_read("alice", times=99),
                        wake_retries=2)
    eng = _engine(cfg, params, store=store)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    eng.hibernate("alice")
    eng.wake("alice")
    eng.run(8)
    eng.flush_wakes()
    # retries exhausted, but the snapshot is intact: HIBERNATED, not LOST
    assert eng.registry.get("alice").status == HIBERNATED
    assert eng.stats["wake_failures"] == 1 and eng.stats["lost_agents"] == 0
    assert any(e["event"] == "wake_failed" for e in eng.history)
    store.faults = None  # the flaky disk recovers
    view = eng.wake("alice", wait=True)
    assert view.active and eng.registry.get("alice").status == ACTIVE


@pytest.mark.filterwarnings("ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_worker_death_mid_wake_heals_and_engine_continues(setup):
    cfg, params = setup
    import tempfile

    store = _cold_store(tempfile.mkdtemp(prefix="resil_worker_"),
                        faults=FaultInjector().kill_worker_on_read("alice"))
    eng = _engine(cfg, params, store=store)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    eng.hibernate("alice")
    eng.wake("alice")
    deadline = time.monotonic() + 30
    while store._worker.is_alive() and time.monotonic() < deadline:
        time.sleep(0.01)
    eng.run(8)          # boundary ops heal the worker + fail the wake
    eng.flush_wakes()
    assert store.stats["worker_respawns"] == 1
    assert eng.registry.get("alice").status == HIBERNATED  # blob intact
    store.faults = None
    assert eng.wake("alice", wait=True).active


def test_wake_deadline_degrades_blocked_promotion(setup):
    cfg, params = setup
    import tempfile

    release = threading.Event()
    store = _cold_store(
        tempfile.mkdtemp(prefix="resil_deadline_"),
        faults=FaultInjector().block_put("alice", release=release, timeout=30),
    )
    eng = _engine(cfg, params, store=store)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    eng.hibernate("alice")
    eng.wake("alice", deadline_s=0.05)
    time.sleep(0.2)
    eng.run(8)   # the overdue ticket expires at the boundary, engine ticks on
    eng.flush_wakes()
    assert eng.registry.get("alice").status == HIBERNATED
    assert eng.stats["wake_failures"] == 1
    release.set()
    store.faults = None
    assert eng.wake("alice", wait=True).active  # second attempt lands


def test_fault_injected_wake_overlap_region_zero_transfers(setup):
    """The acceptance bar's zero-transfer invariant UNDER fault injection:
    a wake that retried through transient faults commits between the ring
    fetch and the next dispatch with the overlap region still issuing zero
    device transfers."""
    cfg, params = setup
    import tempfile

    store = _cold_store(tempfile.mkdtemp(prefix="resil_guard_"),
                        faults=FaultInjector().fail_read("alice", times=2))
    eng = _engine(cfg, params, store=store)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    eng.hibernate("alice")
    eng.submit(PROMPT_B, lane=0, agent_id="bob")
    eng.drain()
    eng.wake("alice")
    eng._wake_tickets["alice"].result(timeout=60)  # retried, then landed
    assert store.stats["wake_retries"] == 2

    eng._dispatch_window(4)                       # window t
    eng._prefetch_rings()
    rings = eng._fetch_rings()
    assert eng._commit_ready_wakes(mark_fresh=True) == 1
    alice = eng.mains[1]
    assert alice.agent_id == "alice" and alice.active
    with jax.transfer_guard("disallow"):
        assert eng._gate(rings, 4)
        eng._dispatch_window(4)                   # window t+1: alice aboard
        eng._postprocess(rings, 4, overlapped=True)
    eng.drain()
    # and the resumed stream is still the fault-free reference prefix
    ref = _engine(cfg, params)
    ref.submit(PROMPT_A, lane=0, agent_id="alice")
    ref.run(20)
    assert alice.tokens == ref.mains[0].tokens[: len(alice.tokens)]


# ---------------------------------------------------------------------------
# server: per-request wake deadlines + per-request degradation
# ---------------------------------------------------------------------------

def _server(cfg, params, store=None, n_lanes=2):
    return BatchServer(
        params, cfg, ByteTokenizer(cfg.vocab_size), n_lanes=n_lanes,
        capacity=128, sampling=SamplingParams(greedy=True), store=store,
    )


def test_server_unpark_deadline_fails_only_that_request(setup):
    cfg, params = setup
    import tempfile

    release = threading.Event()
    store = SynapseStore(
        warm_capacity_bytes=1,
        cold_dir=tempfile.mkdtemp(prefix="resil_srv_"),
        wake_backoff_s=0.001,
    )
    srv = _server(cfg, params, store=store)
    r1 = srv.submit(PROMPT_A, max_new_tokens=24)
    r2 = srv.submit(PROMPT_B, max_new_tokens=24)
    for _ in range(2):
        srv.tick()
    assert srv.park(r1) and srv.park(r2)
    # the short block timeout lets the single prefetch worker free itself
    # to serve r2 after r1's deadline has already expired host-side
    store.faults = FaultInjector().block_put(f"req{r1}", release=release,
                                            timeout=0.5)
    srv.unpark(r1, deadline_s=0.05)
    srv.unpark(r2)
    done = srv.run_until_done()
    release.set()
    by_rid = {r.rid: r for r in done}
    assert by_rid[r1].error is not None and by_rid[r1].done
    assert by_rid[r2].error is None and by_rid[r2].done
    assert len(by_rid[r2].tokens) == by_rid[r2].prompt_len + 24
    assert srv.stats["lost_requests"] == 1


def test_server_lost_parked_snapshot_degrades_per_request(setup):
    """AgentOS-style per-request degradation: one corrupt parked blob fails
    ONE request (error recorded); the other parked request resumes bitwise
    vs a never-parked reference."""
    cfg, params = setup
    import tempfile

    ref_srv = _server(cfg, params)
    rr = ref_srv.submit(PROMPT_B, max_new_tokens=24)
    ref_done = {r.rid: r for r in ref_srv.run_until_done()}

    store = SynapseStore(
        warm_capacity_bytes=1,
        cold_dir=tempfile.mkdtemp(prefix="resil_srv2_"),
        wake_backoff_s=0.001,
    )
    srv = _server(cfg, params, store=store)
    r1 = srv.submit(PROMPT_A, max_new_tokens=24)
    r2 = srv.submit(PROMPT_B, max_new_tokens=24)
    for _ in range(2):
        srv.tick()
    store.faults = FaultInjector().flip_write(f"req{r1}")
    assert srv.park(r1) and srv.park(r2)
    srv.unpark(r1)
    srv.unpark(r2)
    done = {r.rid: r for r in srv.run_until_done()}
    assert done[r1].error is not None
    assert done[r2].error is None and done[r2].done
    assert store.stats["quarantined"] == 1
    # r2's stream matches the never-parked reference bitwise
    assert done[r2].tokens == ref_done[rr].tokens
