"""Topological Synapse properties (paper §3.3) — incl. hypothesis-based
invariants of the hybrid density-coverage selection."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_tools

given, settings, st = hypothesis_tools()  # stubs skip ONLY the property tests

from repro.configs import get_config
from repro.core import synapse as synapse_lib
from repro.models import cache as cache_lib
from repro.models import model as model_lib


def _full_cache(key, B, T, hkv, d, length=None):
    ks = jax.random.split(key, 3)
    return cache_lib.FullCache(
        k=jax.random.normal(ks[0], (B, T, hkv, d)),
        v=jax.random.normal(ks[1], (B, T, hkv, d)),
        pos=jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T)),
        score=jax.random.uniform(ks[2], (B, T)),
        length=jnp.full((B,), T if length is None else length, jnp.int32),
    )


@settings(max_examples=20, deadline=None)
@given(
    T=st.integers(8, 64),
    k=st.integers(1, 16),
    alpha=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_selection_invariants(T, k, alpha, seed):
    """Selected indices are unique, valid, and k of them (when T >= k)."""
    k = min(k, T)
    B, hkv, d = 2, 2, 16
    cache = _full_cache(jax.random.key(seed), B, T, hkv, d)
    q = jax.random.normal(jax.random.key(seed + 1), (B, 4, d))
    policy = synapse_lib.SynapsePolicy(alpha=alpha)
    valid = jnp.ones((B, T), bool)
    density = synapse_lib.attention_density(q, cache.k, valid)
    idx, score, picked = synapse_lib.select_landmarks(cache.k, valid, density, k, policy)
    idx_np = np.asarray(idx)
    assert idx_np.shape == (B, k)
    for b in range(B):
        assert len(set(idx_np[b].tolist())) == k  # unique
        assert (idx_np[b] >= 0).all() and (idx_np[b] < T).all()
    assert bool(picked.all())


def test_pure_density_selects_top_attention():
    """alpha=1 reduces to the paper's pure attention-score summation top-k."""
    B, T, hkv, d, k = 1, 32, 1, 16, 4
    cache = _full_cache(jax.random.key(0), B, T, hkv, d)
    q = jax.random.normal(jax.random.key(1), (B, 2, d))
    valid = jnp.ones((B, T), bool)
    density = synapse_lib.attention_density(q, cache.k, valid)
    idx, _, _ = synapse_lib.select_landmarks(
        cache.k, valid, density, k, synapse_lib.SynapsePolicy(alpha=1.0)
    )
    expect = jnp.argsort(-density, axis=-1)[:, :k]
    assert set(np.asarray(idx)[0].tolist()) == set(np.asarray(expect)[0].tolist())


def test_pure_coverage_is_farthest_point():
    """alpha=0: greedy maxmin — every new landmark is the farthest point
    from the current set (classic witness-landmark construction)."""
    B, T, hkv, d, k = 1, 24, 1, 8, 6
    cache = _full_cache(jax.random.key(3), B, T, hkv, d)
    q = jax.random.normal(jax.random.key(4), (B, 2, d))
    valid = jnp.ones((B, T), bool)
    density = synapse_lib.attention_density(q, cache.k, valid)
    idx, _, _ = synapse_lib.select_landmarks(
        cache.k, valid, density, k, synapse_lib.SynapsePolicy(alpha=0.0, coverage_cap=1e9)
    )
    pooled = np.asarray(cache.k.mean(axis=2))[0]
    chosen = np.asarray(idx)[0].tolist()
    # replay greedy farthest-point (after arbitrary argmax first pick)
    sel = [chosen[0]]
    for step in range(1, k):
        dmin = np.min(
            np.linalg.norm(pooled[:, None, :] - pooled[np.asarray(sel)][None], axis=-1), axis=1
        )
        dmin[np.asarray(sel)] = -np.inf
        assert dmin[chosen[step]] == pytest.approx(np.max(dmin), rel=1e-5), step
        sel.append(chosen[step])


def test_coverage_reduces_hausdorff():
    """Pure-coverage (alpha=0) landmarks have a lower Hausdorff distance to
    the key cloud than pure-density top-k (the TDA claim of [1]); the hybrid
    interpolates."""
    B, T, hkv, d, k = 1, 128, 1, 16, 8
    cache = _full_cache(jax.random.key(7), B, T, hkv, d)
    q = jax.random.normal(jax.random.key(8), (B, 2, d))
    valid = jnp.ones((B, T), bool)
    density = synapse_lib.attention_density(q, cache.k, valid)
    pooled = np.asarray(cache.k.mean(axis=2))[0]

    def hausdorff(idx):
        lm = pooled[np.asarray(idx)[0]]
        dmin = np.min(np.linalg.norm(pooled[:, None] - lm[None], axis=-1), axis=1)
        return float(np.max(dmin))

    idx_dens, _, _ = synapse_lib.select_landmarks(
        cache.k, valid, density, k, synapse_lib.SynapsePolicy(alpha=1.0)
    )
    idx_cov, _, _ = synapse_lib.select_landmarks(
        cache.k, valid, density, k, synapse_lib.SynapsePolicy(alpha=0.0, coverage_cap=1e9)
    )
    assert hausdorff(idx_cov) <= hausdorff(idx_dens) + 1e-6


def test_compress_respects_short_prompt():
    B, T, hkv, d, k = 2, 16, 2, 16, 32  # k > T
    cfg = dataclasses.replace(
        get_config("qwen3-8b", reduced=True), compute_dtype="float32"
    )
    cache = _full_cache(jax.random.key(0), B, T, cfg.n_kv_heads, cfg.d_head, length=10)
    q = jax.random.normal(jax.random.key(1), (B, cfg.n_heads, cfg.d_head))
    syn = synapse_lib.compress(cfg, cache, q, k, window=8, n_inject=2)
    assert int(syn.lm_count[0]) == 10  # only the valid prefix
    assert syn.lm_k.shape[1] == k


def test_compression_ratio_is_98_percent():
    """Paper claim: k=64 on a 4k context = 98.4% token reduction; the synapse
    bytes shrink accordingly."""
    cfg = get_config("qwen2.5-0.5b")
    L_ctx = 4096
    full = cache_lib.init_full_cache(cfg, 1, L_ctx)
    syn = cache_lib.init_synapse_cache(cfg, 1, n_landmarks=64, window=0 or 1, n_inject=1)
    ratio = 1 - 64 / L_ctx
    assert ratio > 0.98
    assert cache_lib.cache_bytes(syn) < cache_lib.cache_bytes(full) * 0.05


def test_streaming_eviction_promotes_high_scores():
    """A token that received heavy attention while in the window must be
    promoted to landmark when it graduates."""
    cfg = dataclasses.replace(get_config("qwen3-8b", reduced=True), compute_dtype="float32")
    params = model_lib.init_params(jax.random.key(0), cfg)
    B, W, K = 1, 8, 4
    spec = model_lib.CacheSpec(kind="synapse", n_landmarks=K, window=W, n_inject=1)
    caches = model_lib.init_caches(cfg, B, spec)
    tok = jax.random.randint(jax.random.key(2), (B, 64), 0, cfg.vocab_size)
    spec_full = spec
    # run enough decode steps to overflow the window several times
    cache0 = jax.tree.map(lambda a: a, caches)
    c = caches
    for t in range(24):
        pos = jnp.full((B,), t, jnp.int32)
        _, _, c = model_lib.decode_step(
            params, cfg, {"tokens": tok[:, t], "positions": pos}, c, spec=spec_full
        )
    lm_count = int(jax.tree.leaves(c.groups[0])[0].shape[0] and np.asarray(c.groups[0].lm_count)[0, 0])
    assert lm_count > 0  # landmarks were populated by graduation
    assert int(np.asarray(c.groups[0].length)[0, 0]) == 24
