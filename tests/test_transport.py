"""HTTP/SSE transport over the serving front-end (ISSUE 10).

The wire contract this suite pins down:

* PARITY — the concatenated ``text`` fields of a ``POST /v1/generate``
  SSE stream are bitwise equal to the in-process :class:`TokenStream`
  text for the same request, on BOTH backends (BatchServer and
  CortexEngine), including multi-byte codepoints split across chunk
  boundaries (JSON escaping carries them exactly);
* BACK-PRESSURE — a full :class:`FairQueue` answers HTTP 429 with a
  ``Retry-After`` header (mapped from :class:`AdmissionError`, counted);
  a client that stalls mid-stream (never drains its socket) trips the
  write timeout or the stream's bounded backlog and gets ONLY its own
  request cancelled — concurrent healthy streams finish with parity;
* DISCONNECT — an abrupt client close mid-stream is detected and routed
  through the observable-cancel path: the request lands in
  ``finished``/``stats`` as "cancelled" and other lanes keep bitwise
  parity;
* CONTROL PLANE — ``/v1/metrics`` serves :meth:`metrics` as JSON,
  ``/v1/cancel/<rid>`` cancels queued and running requests over the
  wire, malformed bodies answer 400, unknown paths 404.
"""
import dataclasses
import threading
import time

import jax
import pytest

from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.frontend import ServingFrontend
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer
from repro.serving.transport import (
    SSEClient,
    TransportServer,
    generate_sync,
    http_json,
)


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _batch_frontend(cfg, params, *, n_lanes=2, **kw):
    srv = BatchServer(params, cfg, ByteTokenizer(cfg.vocab_size),
                      n_lanes=n_lanes, capacity=256,
                      sampling=SamplingParams(greedy=True))
    return ServingFrontend(srv, **kw)


def _wait(pred, timeout=90.0, step=0.05) -> bool:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(step)
    return False


# ---------------------------------------------------------------------------
# parity
# ---------------------------------------------------------------------------

def test_concurrent_clients_bitwise_parity_batch(setup):
    cfg, params = setup
    fe = _batch_frontend(cfg, params)
    with TransportServer(fe) as srv:
        results = [None] * 4

        def client(i):
            results[i] = generate_sync(
                srv.host, srv.port, f"wire prompt {i} é∑",
                tenant="gold" if i % 2 == 0 else "free", max_new_tokens=16,
            )

        threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not any(t.is_alive() for t in threads)

        finished = {r.rid: r for r in fe.backend.finished}
        for i, out in enumerate(results):
            assert out["http_status"] == 200
            assert out["status"] == "ok" and out["error"] is None
            req = fe.requests[out["rid"]]
            # wire text == in-process stream text == one-shot decode, bitwise
            assert out["text"] == req.stream.text
            fin = finished[req.backend_id]
            assert out["text"] == fe.backend.tok.decode(
                fin.tokens[fin.prompt_len:]
            )
        assert srv.stats["streams_opened"] == 4
        assert srv.stats["streams_ok"] == 4
        assert srv.stats["disconnects"] == 0


def test_stream_parity_engine_backend(setup):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=2, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=True,
    )
    fe = ServingFrontend(eng, tenants={"t": 1.0})
    with TransportServer(fe) as srv:
        out = generate_sync(srv.host, srv.port, "engine wire prompt é∑",
                            tenant="t", max_new_tokens=10)
        assert out["http_status"] == 200 and out["status"] == "ok"
        req = fe.requests[out["rid"]]
        assert out["text"] == req.stream.text
        view = next(m for m in eng.mains if m.agent_id == req.backend_id)
        # wire text == final view text minus prompt == one-shot decode
        assert out["text"] == view.text[len(req.prompt):] \
            == tok.decode(view.tokens[view.prompt_len:])


def test_sse_event_shape(setup):
    cfg, params = setup
    fe = _batch_frontend(cfg, params)
    with TransportServer(fe) as srv:
        out = generate_sync(srv.host, srv.port, "shape check",
                            max_new_tokens=8)
        evs = out["events"]
        assert evs[0] == {"rid": out["rid"]}
        assert evs[-1]["done"] is True and evs[-1]["status"] == "ok"
        for ev in evs[1:-1]:
            assert set(ev) == {"text"}
        assert out["headers"]["x-request-id"] == str(out["rid"])
        assert out["headers"]["content-type"].startswith("text/event-stream")


# ---------------------------------------------------------------------------
# back-pressure: 429 on a full queue
# ---------------------------------------------------------------------------

def test_full_queue_answers_429_with_retry_after(setup):
    cfg, params = setup
    fe = _batch_frontend(cfg, params, n_lanes=1, max_queue=1)
    with TransportServer(fe, retry_after_s=2.5) as srv:
        # A occupies the single lane (first text event proves admission) ...
        a = SSEClient(srv.host, srv.port)
        a.generate("occupy the lane", max_new_tokens=512)
        a_events = a.events()
        a_rid = next(a_events)["rid"]
        assert "text" in next(a_events)
        # ... B fills the one-deep admission queue (rid event is immediate,
        # admission is not — A holds the lane) ...
        b = SSEClient(srv.host, srv.port)
        b.generate("wait in queue", max_new_tokens=512)
        b_rid = next(b.events())["rid"]
        assert _wait(lambda: len(fe.fq) == 1, timeout=10)
        # ... so C is rejected on the wire with explicit retry advice
        out = generate_sync(srv.host, srv.port, "one too many",
                            max_new_tokens=8)
        assert out["http_status"] == 429
        assert out["headers"]["retry-after"] == "2.5"
        assert "admission queue full" in out["body"]["error"]
        assert srv.stats["rejected_429"] == 1
        assert fe.metrics()["tenants"]["default"]["rejected"] == 1

        # cancel A (running: deferred to a boundary) and B (queued:
        # immediate) over the wire; both streams end observably
        code, body = http_json(srv.host, srv.port, "POST",
                               f"/v1/cancel/{b_rid}")
        assert code == 200 and body["cancelled"] is True
        code, _ = http_json(srv.host, srv.port, "POST", f"/v1/cancel/{a_rid}")
        assert code == 200
        for client, events in ((a, a_events), (b, b.events())):
            last = None
            for ev in events:
                last = ev
            assert last["done"] is True and last["status"] == "cancelled"
            client.close()
        assert _wait(lambda: fe.pending() == 0, timeout=30)
        code, body = http_json(srv.host, srv.port, "POST", "/v1/cancel/999")
        assert code == 404 and body["cancelled"] is False


# ---------------------------------------------------------------------------
# disconnect and stalled clients
# ---------------------------------------------------------------------------

def test_midstream_disconnect_cancels_only_that_request(setup):
    cfg, params = setup
    fe = _batch_frontend(cfg, params)
    with TransportServer(fe, poll_s=0.02, pump_ticks=16) as srv:
        # reference run first, alone, on the SAME transport: greedy decoding
        # is lane-composition invariant, so this is the bitwise yardstick
        ref = generate_sync(srv.host, srv.port, "survivor prompt é∑",
                            max_new_tokens=24)
        assert ref["status"] == "ok"

        # victim stream opens, reads its rid, then vanishes mid-generation
        victim = SSEClient(srv.host, srv.port)
        victim.generate("doomed client", max_new_tokens=4096)
        v_rid = next(victim.events())["rid"]
        assert _wait(lambda: fe.requests[v_rid].status == "running",
                     timeout=30)
        victim.close()  # abrupt: no FIN handshake beyond the TCP close

        # the survivor runs while the disconnect is being detected/applied
        out = generate_sync(srv.host, srv.port, "survivor prompt é∑",
                            max_new_tokens=24)
        assert out["status"] == "ok"
        assert out["text"] == ref["text"]  # neighbor's death changed nothing

        assert _wait(lambda: fe.requests[v_rid].status == "cancelled",
                     timeout=60)
        vreq = fe.requests[v_rid]
        fin = {r.rid: r for r in fe.backend.finished}[vreq.backend_id]
        assert fin.status == "cancelled"  # observable in finished/stats
        assert fe.backend.stats["cancelled"] == 1
        assert _wait(lambda: srv.stats["disconnects"] >= 1, timeout=10)
        assert _wait(lambda: fe.pending() == 0, timeout=30)


def test_stalled_client_cancelled_others_fine(setup):
    cfg, params = setup
    fe = _batch_frontend(cfg, params)
    # tiny kernel buffers + short write timeout + bounded stream backlog:
    # a reader that never drains trips back-pressure within a few hundred
    # tokens instead of a few MB
    with TransportServer(fe, sndbuf=4096, write_timeout_s=0.5,
                         max_buffered_chars=256, poll_s=0.02,
                         pump_ticks=16) as srv:
        stalled = SSEClient(srv.host, srv.port, rcvbuf=2048)
        stalled.generate("stalled reader", max_new_tokens=4096)
        # read NOTHING further: the socket fills, the handler's writes
        # time out (or the unread stream backlog overflows), and only
        # this request dies
        assert _wait(lambda: any(r.prompt == "stalled reader"
                                 for r in fe.requests.values()), timeout=30)
        s_rid = next(r.rid for r in fe.requests.values()
                     if r.prompt == "stalled reader")

        healthy = generate_sync(srv.host, srv.port, "healthy reader",
                                max_new_tokens=16)
        assert healthy["status"] == "ok"
        hreq = fe.requests[healthy["rid"]]
        assert healthy["text"] == hreq.stream.text  # parity, undisturbed

        assert _wait(lambda: fe.requests[s_rid].status == "cancelled",
                     timeout=90)
        assert _wait(lambda: fe.pending() == 0, timeout=30)
        # at least one back-pressure mechanism observably fired
        assert (srv.stats["stalled_writes"] >= 1
                or srv.stats["disconnects"] >= 1
                or fe.requests[s_rid].stream.overflowed)
        stalled.close()


# ---------------------------------------------------------------------------
# control plane
# ---------------------------------------------------------------------------

def test_metrics_healthz_and_errors(setup):
    cfg, params = setup
    fe = _batch_frontend(cfg, params, tenants={"gold": 4.0})
    with TransportServer(fe) as srv:
        out = generate_sync(srv.host, srv.port, "metrics seed",
                            tenant="gold", max_new_tokens=8)
        assert out["status"] == "ok"

        code, m = http_json(srv.host, srv.port, "GET", "/v1/metrics")
        assert code == 200
        assert m["backend"] == "batch" and m["completed"] == 1
        assert m["tenants"]["gold"]["tokens_out"] == 8
        assert {"requests", "fairness", "ttft_s", "tick_latency_s"} <= set(m)

        code, h = http_json(srv.host, srv.port, "GET", "/healthz")
        assert code == 200 and h["ok"] is True and h["pending"] == 0

        code, body = http_json(srv.host, srv.port, "POST", "/v1/generate",
                               {"tenant": "gold"})  # no prompt
        assert code == 400 and "bad request" in body["error"]
        code, body = http_json(srv.host, srv.port, "POST", "/v1/generate",
                               {"prompt": "x", "sampling": {"beam": 4}})
        assert code == 400 and "beam" in body["error"]
        code, _ = http_json(srv.host, srv.port, "GET", "/v1/nope")
        assert code == 404
        code, _ = http_json(srv.host, srv.port, "POST", "/v1/cancel/abc")
        assert code == 400


def test_sampling_params_ride_the_wire(setup):
    cfg, params = setup
    fe = _batch_frontend(cfg, params)
    with TransportServer(fe) as srv:
        out = generate_sync(srv.host, srv.port, "sampled over http",
                            max_new_tokens=8,
                            sampling={"greedy": True})
        assert out["status"] == "ok"
        req = fe.requests[out["rid"]]
        assert req.sampling is not None and req.sampling.greedy is True
