"""Sampler edge cases (ISSUE 5): degenerate filter settings, near-zero
temperatures, and mixed greedy/filtered lanes must match the reference
single-lane :func:`repro.serving.sampler.sample` semantics.

Bitwise assertions where the contract is bitwise (greedy lanes, disabled
filters encoded two ways); support assertions where the paths legitimately
assign Gumbel noise differently (stochastic draws must land inside the
reference path's allowed token set — and always do for every seed)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import hypothesis_tools
from repro.serving.sampler import (
    SamplingParams, lane_params, sample, sample_lanes, stack_lane_params,
)


def _logits(key, b, v):
    return jax.random.normal(jax.random.key(key), (b, v)) * 3.0


def _ref_allowed(row: np.ndarray, p: SamplingParams) -> np.ndarray:
    """Boolean support of the reference sample() path for one lane, numpy
    mirror of its sequential top-k -> (renormalized) top-p filtering."""
    v = row.shape[0]
    if p.greedy or p.temperature <= 0.0:
        out = np.zeros(v, bool)
        out[int(np.argmax(row))] = True
        return out
    x = row / max(p.temperature, 1e-6)
    if p.top_k > 0:
        kth = np.sort(x)[::-1][min(p.top_k, v) - 1]
        x = np.where(x < kth, -np.inf, x)
    if p.top_p < 1.0:
        s = np.sort(x)[::-1]
        probs = np.exp(s - s.max())
        probs = probs / probs.sum()
        cum = np.cumsum(probs)
        cutoff = s[int((cum < p.top_p).sum())]
        x = np.where(x < cutoff, -np.inf, x)
    return np.isfinite(x)


def test_top_k_geq_vocab_equals_disabled_bitwise():
    """top_k >= vocab is the same program as top_k=0 (disabled): identical
    rank mask, identical Gumbel assignment, identical draw."""
    logits = _logits(0, 4, 97)
    a = stack_lane_params([SamplingParams(temperature=1.0, top_k=97)] * 4)
    b = stack_lane_params([SamplingParams(temperature=1.0, top_k=0)] * 4)
    c = stack_lane_params([SamplingParams(temperature=1.0, top_k=500)] * 4)
    for seed in range(16):
        key = jax.random.key(seed)
        ta = sample_lanes(key, logits, a)
        np.testing.assert_array_equal(np.asarray(ta),
                                      np.asarray(sample_lanes(key, logits, b)))
        np.testing.assert_array_equal(np.asarray(ta),
                                      np.asarray(sample_lanes(key, logits, c)))


def test_top_p_one_is_disabled_and_full_support():
    """top_p=1.0 disables the nucleus filter: with a small vocab every token
    stays reachable (including through the filtered program), matching the
    reference path's full support."""
    logits = jnp.zeros((1, 5))  # uniform: all tokens equally likely
    lanes = stack_lane_params([SamplingParams(temperature=1.0, top_p=1.0)])
    seen_filtered, seen_plain = set(), set()
    for seed in range(64):
        key = jax.random.key(seed)
        seen_filtered.add(int(sample_lanes(key, logits, lanes, use_filters=True)[0]))
        seen_plain.add(int(sample_lanes(key, logits, lanes, use_filters=False)[0]))
    assert seen_filtered == seen_plain == set(range(5))


def test_temperature_near_zero_equals_argmax():
    """temperature -> 0+ must converge to argmax exactly (the clamp shared
    with sample() keeps the scaled logits finite); temperature == 0 is the
    greedy encoding. All four spellings agree bitwise."""
    logits = _logits(3, 5, 211)
    am = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
    for t in (0.0, 1e-30, 1e-12, 1e-7):
        lanes = stack_lane_params([SamplingParams(temperature=t)] * 5)
        for seed in range(4):
            got = np.asarray(sample_lanes(jax.random.key(seed), logits, lanes))
            assert got.dtype == np.int32
            np.testing.assert_array_equal(got, am, err_msg=f"t={t}")
    greedy = stack_lane_params([SamplingParams(greedy=True)] * 5)
    np.testing.assert_array_equal(
        np.asarray(sample_lanes(jax.random.key(0), logits, greedy)), am
    )


def test_temperature_epsilon_matches_reference_sample():
    """The clamp is the SAME clamp sample() applies, so the tiny-temperature
    single-lane reference agrees token-for-token (both reduce to argmax)."""
    logits = _logits(9, 3, 64)
    for t in (1e-30, 1e-9):
        ref = np.asarray(sample(jax.random.key(1), logits, SamplingParams(temperature=t)))
        got = np.asarray(sample_lanes(
            jax.random.key(1), logits, lane_params(SamplingParams(temperature=t), 3)
        ))
        np.testing.assert_array_equal(ref, got)


def test_mixed_greedy_filtered_lanes_match_reference_support():
    """One shared dispatch, four different lane policies: every draw lands
    in that lane's reference-path support, and the greedy lane is bitwise
    argmax for every seed (unaffected by its stochastic neighbors)."""
    ps = [
        SamplingParams(greedy=True),
        SamplingParams(temperature=0.8, top_k=3),
        SamplingParams(temperature=1.1, top_p=0.7),
        SamplingParams(temperature=2.0),
    ]
    logits = _logits(7, len(ps), 89)
    rows = np.asarray(logits)
    allowed = [_ref_allowed(rows[i], p) for i, p in enumerate(ps)]
    lanes = stack_lane_params(ps)
    am0 = int(np.argmax(rows[0]))
    for seed in range(64):
        got = np.asarray(sample_lanes(jax.random.key(seed), logits, lanes))
        assert int(got[0]) == am0
        for i in range(len(ps)):
            assert allowed[i][got[i]], (seed, i, got[i])
    # the filters actually bite: top_k=3 must exclude most of the vocab
    assert allowed[1].sum() == 3 and 0 < allowed[2].sum() < 89


# ---------------------------------------------------------------------------
# property-based edge sweep (hypothesis optional — gated via conftest)
# ---------------------------------------------------------------------------
given, settings, st = hypothesis_tools()


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**20),
    v=st.integers(min_value=4, max_value=160),
    temp=st.sampled_from([0.0, 1e-9, 1e-6, 0.3, 1.0, 2.5]),
    top_k=st.sampled_from([0, 1, 3, 7, 1000]),
    top_p=st.sampled_from([1.0, 0.9, 0.4, 1e-6]),
)
def test_property_draws_stay_in_reference_support(seed, v, temp, top_k, top_p):
    p = SamplingParams(temperature=temp, top_k=top_k, top_p=top_p)
    logits = _logits(seed, 2, v)
    rows = np.asarray(logits)
    lanes = stack_lane_params([p, SamplingParams(greedy=True)])
    got = np.asarray(sample_lanes(jax.random.key(seed ^ 0x5EED), logits, lanes))
    allowed = _ref_allowed(rows[0], p)
    assert allowed[got[0]], (got[0], np.flatnonzero(allowed))
    assert int(got[1]) == int(np.argmax(rows[1]))
