"""Prefill + decode must reproduce the full-sequence forward (per arch
family, fp32 to isolate algorithmic error from dtype noise)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import model as model_lib

TOL = 5e-4


def _roundtrip(cfg, P_frac=0.75, S=32, B=2, spec=None):
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits_ref, _ = model_lib.forward(params, cfg, {"tokens": tok})
    spec = spec or model_lib.CacheSpec(kind="full", capacity=S + 8)
    caches = model_lib.init_caches(cfg, B, spec)
    P = int(S * P_frac)
    lg, hid, caches = model_lib.prefill(params, cfg, {"tokens": tok[:, :P]}, caches, spec=spec)
    errs = [float(jnp.abs(lg - logits_ref[:, P - 1]).max())]
    for t in range(P, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg, hid, caches = model_lib.decode_step(
            params, cfg, {"tokens": tok[:, t], "positions": pos}, caches, spec=spec
        )
        errs.append(float(jnp.abs(lg - logits_ref[:, t]).max()))
    return errs


@pytest.mark.parametrize(
    "arch", ["qwen3-8b", "qwen1.5-110b", "smollm-135m", "qwen2.5-0.5b", "zamba2-1.2b", "rwkv6-1.6b"]
)
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_config(arch, reduced=True), compute_dtype="float32")
    errs = _roundtrip(cfg)
    assert max(errs) < TOL, errs


def test_mla_decode_matches_forward():
    # isolate MLA from MoE router top-k flips (tiny-perturbation sensitivity)
    cfg = dataclasses.replace(
        get_config("deepseek-v2-236b", reduced=True),
        compute_dtype="float32",
        n_experts=0,
        n_shared_experts=0,
        experts_per_token=0,
        first_k_dense=0,
    )
    errs = _roundtrip(cfg)
    assert max(errs) < TOL, errs


def test_moe_decode_router_agreement():
    """With MoE, two caveats: capacity drops depend on the token batch (so we
    run dropless here), and decode logits can diverge when the router flips
    on ~1e-6 hidden perturbations. Assert prefill is exact (dropless) and
    decode stays finite."""
    cfg = dataclasses.replace(
        get_config("deepseek-v2-236b", reduced=True),
        compute_dtype="float32",
        moe_capacity_factor=100.0,
    )
    errs = _roundtrip(cfg)
    assert all(jnp.isfinite(jnp.asarray(errs))), errs
    assert errs[0] < TOL  # prefill itself exact


def test_synapse_cache_exact_when_lossless():
    """k >= prompt length + window >= generated: the synapse cache must be
    exact (compression only drops information when over capacity)."""
    cfg = dataclasses.replace(get_config("qwen3-8b", reduced=True), compute_dtype="float32")
    S = 48
    spec = model_lib.CacheSpec(kind="synapse", n_landmarks=64, window=64, n_inject=4)
    errs = _roundtrip(cfg, S=S, spec=spec)
    assert max(errs) < TOL, errs


def test_vlm_decode_runs():
    cfg = dataclasses.replace(get_config("qwen2-vl-72b", reduced=True), compute_dtype="float32")
    B, S = 2, 16
    params = model_lib.init_params(jax.random.key(0), cfg)
    emb = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, None], (B, 3, S))
    spec = model_lib.CacheSpec(kind="full", capacity=S + 4)
    caches = model_lib.init_caches(cfg, B, spec)
    lg, hid, caches = model_lib.prefill(
        params, cfg, {"embeds": emb, "positions": pos}, caches, spec=spec
    )
    tok = jnp.zeros((B,), jnp.int32)
    pos1 = jnp.full((B, 3), S, jnp.int32)
    lg2, _, _ = model_lib.decode_step(
        params, cfg, {"tokens": tok, "positions": pos1}, caches, spec=spec
    )
    assert lg2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg2).all())
