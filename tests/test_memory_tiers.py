"""Tiered synapse memory (ISSUE 7 acceptance criteria).

The contract this suite pins down:

* STORE — `SynapseStore` round-trips snapshots BITWISE through the warm
  (host numpy) tier, demotes LRU entries to the cold (zstd disk) tier when
  over `warm_capacity_bytes` — skipping (and counting) demotions when the
  optional zstd backing is absent rather than raising mid-run — and
  promotes asynchronously via `prefetch()` WakeTickets on a daemon thread;
* ZERO DEVICE BYTES — a hibernated agent vanishes from
  `memory_report()['per_agent_bytes']`: its context costs exactly zero
  device bytes and reappears under `tiers.warm_bytes`/`cold_bytes`, with
  the registered-vs-active split in `agents`;
* PARITY — an agent hibernated at a drain boundary and woken later (into a
  DIFFERENT lane) replays its greedy stream bitwise: its token stream is a
  prefix-extension of a never-hibernated reference, for main AND side
  agents, on the single-device engine and the forced-8-device lane mesh,
  including randomized hibernate/wake/run interleavings (hypothesis);
* ASYNC WAKE — `wake()` returns immediately; the prefetched buffers commit
  at a window boundary between the ring fetch and the next dispatch, so
  the pipeline never flushes and the overlapped post-processing region
  still issues ZERO device transfers (`jax.transfer_guard("disallow")`);
* POLICY — `submit_agent` evicts the LRU resident when lanes are full
  (refusing only when every main has live side streams),
  `hibernate_idle_ticks` demotes idle mains at boundaries, and mains with
  pending side merges can never hibernate;
* SERVER — `BatchServer.park()/unpark()` continue a request's greedy
  stream bitwise after its KV lane is recycled.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import hypothesis_tools
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_lane_mesh
from repro.memory import (
    ACTIVE,
    HIBERNATED,
    REGISTERED,
    AgentRegistry,
    SynapseStore,
)
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer

N_DEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)
PROMPT_A = "calm text with no tags at all"
PROMPT_B = "another quiet prompt, still tagless"


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _engine(cfg, params, *, n_main=2, max_side=2, sync_every=4,
            side_max_steps=50, mesh=None, store=None, hibernate_idle_ticks=None,
            pipeline=True):
    return CortexEngine(
        Prism(params, cfg), ByteTokenizer(cfg.vocab_size), n_main=n_main,
        max_side=max_side, main_capacity=128, side_max_steps=side_max_steps,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=sync_every, pipeline=pipeline, mesh=mesh, store=store,
        hibernate_idle_ticks=hibernate_idle_ticks,
    )


def _tree_equal_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        assert x.tobytes() == y.tobytes()


def _snap(seed, kb=4):
    rng = np.random.default_rng(seed)
    return {
        "caches": rng.standard_normal(kb * 256).astype(np.float32),
        "tok": np.int32(seed),
        "pos": np.int64(seed * 10),
    }


# ---------------------------------------------------------------------------
# SynapseStore / AgentRegistry units
# ---------------------------------------------------------------------------

def test_store_warm_roundtrip_bitwise():
    store = SynapseStore()
    snap = _snap(1)
    store.put("a", snap)
    assert store.tier_of("a") == "warm"
    _tree_equal_bitwise(snap, store.get_host("a"))
    rep = store.report()
    assert rep["n_warm"] == 1 and rep["n_cold"] == 0
    assert rep["warm_bytes"] == sum(
        np.asarray(x).nbytes for x in jax.tree.leaves(snap)
    )
    store.drop("a")
    assert store.tier_of("a") is None and "a" not in store


def test_store_accepts_device_trees():
    store = SynapseStore()
    dev = jax.tree.map(jax.numpy.asarray, _snap(2))  # int64 narrows w/o x64
    store.put("dev", dev)
    back = store.get_host("dev")
    _tree_equal_bitwise(jax.tree.map(np.asarray, jax.device_get(dev)), back)
    assert all(isinstance(x, np.ndarray) for x in jax.tree.leaves(back))


def test_store_lru_demotes_to_cold(tmp_path):
    # no zstd gate anymore: the framed cold codec falls back to zlib (ISSUE 8)
    one = sum(np.asarray(x).nbytes for x in jax.tree.leaves(_snap(0)))
    store = SynapseStore(warm_capacity_bytes=2 * one, cold_dir=str(tmp_path))
    snaps = {k: _snap(i) for i, k in enumerate("abc")}
    for k, s in snaps.items():
        store.put(k, s)
    # capacity fits two: the LRU entry ("a") spilled to disk
    assert store.tier_of("a") == "cold"
    assert store.tier_of("b") == "warm" and store.tier_of("c") == "warm"
    rep = store.report()
    assert rep["n_cold"] == 1 and rep["cold_bytes"] > 0
    assert rep["cold_raw_bytes"] == one
    assert any(tmp_path.iterdir())
    for k, s in snaps.items():  # cold read is bitwise too
        _tree_equal_bitwise(s, store.get_host(k))
    # re-putting refreshes LRU order: "b" becomes oldest and spills next
    store.put("b", snaps["b"])  # no-op content, LRU refresh
    store.put("a", snaps["a"])  # back to warm; "c" now oldest... cap check
    assert store.stats["demotions"] >= 2
    store.drop("a")
    store.drop("b")
    store.drop("c")
    # only the manifest (the persistent cold-index mirror) may remain —
    # every blob and tmp file must be gone
    leftovers = [
        p.name for p in tmp_path.iterdir()
        if p.suffix != ".tmp" and p.name not in ("MANIFEST.pkl", "quarantine")
    ]
    assert not leftovers, leftovers


def test_store_demotion_skipped_without_cold_backing():
    """No cold_dir (or no zstandard): over-capacity entries stay warm and
    the skip is COUNTED — state is never dropped, nothing raises mid-run."""
    one = sum(np.asarray(x).nbytes for x in jax.tree.leaves(_snap(0)))
    store = SynapseStore(warm_capacity_bytes=one)
    store.put("a", _snap(1))
    store.put("b", _snap(2))
    assert store.tier_of("a") == "warm" and store.tier_of("b") == "warm"
    assert store.stats["demotions_skipped"] >= 1
    assert store.report()["warm_bytes"] == 2 * one


def test_store_prefetch_async_ticket():
    store = SynapseStore()
    snap = _snap(3)
    store.put("a", snap)
    ticket = store.prefetch("a", lambda host: jax.device_put(host))
    got = ticket.result(timeout=30)
    assert ticket.ready()
    assert all(isinstance(x, jax.Array) for x in jax.tree.leaves(got))
    # compare post-device_put (int64 narrows without x64, on both sides)
    _tree_equal_bitwise(jax.device_get(jax.device_put(snap)), jax.device_get(got))
    with pytest.raises(KeyError):
        store.prefetch("missing")
    # a failing put_fn surfaces at result(), not on the engine thread
    bad = store.prefetch("a", lambda host: (_ for _ in ()).throw(RuntimeError("boom")))
    with pytest.raises(RuntimeError, match="boom"):
        bad.result(timeout=30)


def test_registry_transitions_and_lru():
    reg = AgentRegistry()
    for aid in ("a", "b", "c"):
        reg.register(aid, "main")
    assert reg.counts() == {"registered": 3, "active": 0, "hibernated": 0,
                            "lost": 0, "dormant": 3}
    reg.bind("a", 0)
    reg.bind("b", 1)
    assert reg.agent_at(1, "main").agent_id == "b"
    assert reg.lru_active("main").agent_id == "a"
    assert reg.lru_active("main", exclude=("a",)).agent_id == "b"
    reg.hibernate("a", {"x": 1})
    assert reg.get("a").status == HIBERNATED and reg.get("a").saved == {"x": 1}
    assert reg.counts()["hibernated"] == 1 and reg.counts()["dormant"] == 2
    reg.release("a")
    assert reg.get("a").status == REGISTERED and reg.get("a").saved is None
    reg.forget("c")
    assert "c" not in reg and reg.counts()["registered"] == 2


# ---------------------------------------------------------------------------
# Engine: hibernate / wake
# ---------------------------------------------------------------------------

def test_hibernate_zero_device_bytes_and_tier_report(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    rep0 = eng.memory_report()
    alice_bytes = rep0["per_agent_bytes"]["alice"]
    assert alice_bytes > 0
    eng.hibernate("alice")
    rep = eng.memory_report()
    # the acceptance bar: a hibernated agent contributes ~0 device bytes —
    # exactly 0 here, because its lane slice left the device entirely
    assert "alice" not in rep["per_agent_bytes"]
    # warm holds the full snapshot: cache slice + hidden/token/pos scalars
    assert alice_bytes <= rep["tiers"]["warm_bytes"] <= alice_bytes + 4096
    assert rep["tiers"]["hot_bytes"] == rep0["tiers"]["hot_bytes"] - alice_bytes
    assert rep["agents"] == {"registered": 1, "active": 0, "hibernated": 1,
                             "lost": 0, "dormant": 1}
    assert eng.store.tier_of("alice") == "warm"
    assert eng.stats["hibernates"] == 1
    # double-hibernate and waking an active agent are both well-defined
    with pytest.raises(ValueError, match="not active"):
        eng.hibernate("alice")


def test_hibernate_wake_parity_main_different_lane(setup):
    """An agent hibernated at tick 8, displaced by a new resident, and
    woken into the OTHER lane replays its greedy stream bitwise (prefix of
    the never-hibernated reference)."""
    cfg, params = setup
    ref = _engine(cfg, params)
    ref.submit(PROMPT_A, lane=0, agent_id="alice")
    ref.run(20)
    ref_tokens = list(ref.mains[0].tokens)

    eng = _engine(cfg, params)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    parked_len = len(eng.mains[0].tokens)
    eng.hibernate("alice")
    eng.submit(PROMPT_B, lane=0, agent_id="bob")  # lane 0 is recycled
    eng.run(4)
    alice = eng.wake("alice", wait=True)
    assert alice.active and alice.lane == 1  # woke into a different lane
    eng.run(12)
    assert len(alice.tokens) == parked_len + 12
    assert alice.tokens == ref_tokens[: len(alice.tokens)]
    assert eng.stats["wakes"] == 1
    # bob is undisturbed by the wake: his own reference run matches
    ref2 = _engine(cfg, params)
    ref2.submit(PROMPT_B, lane=0, agent_id="bob")
    ref2.run(16)
    assert eng.mains[0].tokens == ref2.mains[0].tokens[: len(eng.mains[0].tokens)]


def test_wake_commits_inside_run_without_flush(setup):
    """`wake()` without wait=True: the commit rides `run()`'s window
    boundaries while other lanes keep decoding — the pipeline stays
    engaged (overlapped drains still happen) and parity holds."""
    cfg, params = setup
    ref = _engine(cfg, params)
    ref.submit(PROMPT_A, lane=0, agent_id="alice")
    ref.run(40)
    ref_tokens = list(ref.mains[0].tokens)

    eng = _engine(cfg, params)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    eng.hibernate("alice")
    eng.submit(PROMPT_B, lane=0, agent_id="bob")
    rec = eng.wake("alice")  # async: returns the still-hibernated record
    assert rec.status == HIBERNATED
    over0 = eng.stats["overlapped_drains"]
    eng.run(24)
    alice = eng.mains[1]
    assert alice.agent_id == "alice" and alice.active
    assert eng.stats["wakes"] == 1
    assert len(alice.tokens) > 8  # advanced after the in-run commit
    assert alice.tokens == ref_tokens[: len(alice.tokens)]
    assert eng.stats["overlapped_drains"] > over0  # pipeline never flushed
    assert any(e["event"] == "wake" for e in eng.history)


def test_wake_overlap_region_zero_transfers(setup):
    """The manual pipelined window, with a wake committed between the ring
    fetch and the next dispatch: the overlapped post-processing region
    (gate + dispatch t+1 + window-t host work) still issues ZERO device
    transfers under `jax.transfer_guard("disallow")`."""
    cfg, params = setup
    eng = _engine(cfg, params)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    eng.hibernate("alice")
    eng.submit(PROMPT_B, lane=0, agent_id="bob")
    eng.drain()
    eng.wake("alice")
    eng._wake_tickets["alice"].result(timeout=60)  # prefetch landed on device

    eng._dispatch_window(4)                      # window t
    eng._prefetch_rings()
    rings = eng._fetch_rings()
    assert eng._commit_ready_wakes(mark_fresh=True) == 1  # boundary commit
    alice = eng.mains[1]
    assert alice.agent_id == "alice" and alice.active
    n_bob = len(eng.mains[0].tokens)
    n_alice = len(alice.tokens)
    with jax.transfer_guard("disallow"):
        assert eng._gate(rings, 4)
        eng._dispatch_window(4)                  # window t+1: alice aboard
        eng._postprocess(rings, 4, overlapped=True)
    # window t predates the wake: only bob's mirror advances...
    assert len(eng.mains[0].tokens) == n_bob + 4
    assert len(alice.tokens) == n_alice
    eng.drain()  # ...window t+1 advances both
    assert len(eng.mains[0].tokens) == n_bob + 8
    assert len(alice.tokens) == n_alice + 4
    # and the resumed stream is still the reference prefix
    ref = _engine(cfg, params)
    ref.submit(PROMPT_A, lane=0, agent_id="alice")
    ref.run(16)
    assert alice.tokens == ref.mains[0].tokens[: len(alice.tokens)]


def test_hibernate_wake_parity_side(setup):
    """Side agents hibernate/wake too: the side stream freezes while
    parked (its step budget does not advance) and resumes bitwise."""
    cfg, params = setup
    ref = _engine(cfg, params)
    m = ref.submit(PROMPT_A, lane=0, agent_id="alice")
    assert ref._spawn_side(m, "probe the claim") is not None
    ref.run(40)
    ref_side = list(ref.sides[0].tokens)

    eng = _engine(cfg, params)
    m = eng.submit(PROMPT_A, lane=0, agent_id="alice")
    assert eng._spawn_side(m, "probe the claim") is not None
    eng.run(28)  # past the task-prompt phase: the side is generating
    side0 = eng.sides[0]
    assert len(side0.tokens) > side0.prompt_len
    parked_len, parked_steps = len(side0.tokens), side0.steps
    eng.hibernate("side0")
    eng.run(4)  # main advances; the parked side (and its budget) does not
    side = eng.wake("side0", wait=True)
    assert side.active
    eng.run(8)
    assert side.steps == parked_steps + 8  # budget frozen while parked
    assert len(side.tokens) == parked_len + 8
    assert side.tokens == ref_side[: len(side.tokens)]
    # the main ran 40 ticks in both engines: bitwise identical
    assert eng.mains[0].tokens == ref.mains[0].tokens


def test_hibernate_refuses_main_with_children(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    m = eng.submit(PROMPT_A, lane=0, agent_id="alice")
    assert eng._spawn_side(m, "child stream") is not None
    with pytest.raises(ValueError, match="side streams still target"):
        eng.hibernate("alice")
    # ...including HIBERNATED children: their merge still targets the lane
    eng.run(4)
    eng.hibernate("side0")
    with pytest.raises(ValueError, match="side streams still target"):
        eng.hibernate("alice")


def test_submit_agent_lru_eviction(setup):
    """Lane-less submits: a full house hibernates the least-recently-bound
    resident, so max lanes bounds *active* agents, not registered ones."""
    cfg, params = setup
    eng = _engine(cfg, params)
    a = eng.submit_agent(PROMPT_A)
    b = eng.submit_agent(PROMPT_B)
    assert {a.lane, b.lane} == {0, 1}
    eng.run(4)
    c = eng.submit_agent("third agent enters")  # evicts a (LRU)
    assert c.active
    assert eng.registry.get(a.agent_id).status == HIBERNATED
    assert eng.registry.get(b.agent_id).status == ACTIVE
    assert eng.store.tier_of(a.agent_id) == "warm"
    rep = eng.memory_report()
    assert rep["agents"]["registered"] == 3
    assert rep["agents"]["active"] == 2 and rep["agents"]["hibernated"] == 1
    # the evictee comes back when a lane frees, stream intact
    parked = len(a.tokens)
    eng.hibernate(b.agent_id)
    woken = eng.wake(a.agent_id, wait=True)
    eng.run(4)
    assert len(woken.tokens) == parked + 4


def test_submit_agent_refuses_when_all_blocked(setup):
    cfg, params = setup
    eng = _engine(cfg, params, n_main=1)
    m = eng.submit_agent(PROMPT_A)
    assert eng._spawn_side(m, "pin the lane") is not None
    with pytest.raises(RuntimeError, match="no evictable resident"):
        eng.submit_agent(PROMPT_B)


def test_auto_hibernate_idle_ticks(setup):
    cfg, params = setup
    eng = _engine(cfg, params, hibernate_idle_ticks=8)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(16)
    rec = eng.registry.get("alice")
    assert rec.status == HIBERNATED
    assert eng.stats["hibernates"] == 1
    assert not eng._any_active()
    assert "alice" in eng.store
    alice = eng.wake("alice", wait=True)
    n = len(alice.tokens)
    eng.run(4)
    assert len(alice.tokens) == n + 4


def test_resubmit_hibernated_id_drops_snapshot(setup):
    """Re-submitting an agent_id that is parked replaces the context
    outright: the stale snapshot and any pending wake are discarded."""
    cfg, params = setup
    eng = _engine(cfg, params)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(4)
    eng.hibernate("alice")
    eng.wake("alice")  # pending ticket, then changed our mind:
    eng.submit(PROMPT_B, lane=0, agent_id="alice")
    assert "alice" not in eng.store
    assert not eng._pending_wakes
    assert eng.registry.get("alice").status == ACTIVE
    eng.run(4)  # no stray commit resurrects the old context
    assert eng.mains[0].agent_id == "alice"
    assert eng.mains[1].active is False


# ---------------------------------------------------------------------------
# Lane-mesh parity
# ---------------------------------------------------------------------------

def _hibernate_script(eng):
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.run(8)
    eng.hibernate("alice")
    eng.submit(PROMPT_B, lane=0, agent_id="bob")
    eng.run(4)
    eng.wake("alice", wait=True)
    eng.run(8)
    return list(eng.mains[0].tokens), list(eng.mains[1].tokens), [
        (e["event"], e.get("agent")) for e in eng.history
    ]


def test_mesh1_hibernate_wake_parity(setup):
    """A 1-device lane mesh exercises the full shard_map + replicated
    gather/scatter wake path inside tier-1."""
    cfg, params = setup
    plain = _hibernate_script(_engine(cfg, params))
    mesh = _hibernate_script(_engine(cfg, params, mesh=make_lane_mesh(1)))
    assert mesh == plain


@needs_mesh
def test_mesh8_hibernate_wake_parity(setup):
    """The greedy contract includes the mesh: hibernate/wake on a real
    8-device lane mesh is bitwise identical to the single-device engine."""
    cfg, params = setup
    plain = _hibernate_script(_engine(cfg, params, max_side=8))
    mesh = _hibernate_script(
        _engine(cfg, params, max_side=8, mesh=make_lane_mesh(8))
    )
    assert mesh == plain


# ---------------------------------------------------------------------------
# Randomized interleavings (hypothesis)
# ---------------------------------------------------------------------------

given, settings, st = hypothesis_tools()

_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("run"), st.integers(min_value=1, max_value=9)),
        st.tuples(st.just("hib"), st.just(0)),
        st.tuples(st.just("wake"), st.just(0)),
    ),
    min_size=3,
    max_size=8,
)


@settings(max_examples=5, deadline=None)
@given(ops=_OPS)
def test_property_churn_parity(setup, ops):
    """Random run/hibernate/wake interleavings: bob (never hibernated, on
    lane 1 in both engines) stays BITWISE identical to the reference, and
    alice's stream is always a prefix of her never-hibernated self."""
    cfg, params = setup
    ref = _engine(cfg, params)
    ref.submit(PROMPT_A, lane=0, agent_id="alice")
    ref.submit(PROMPT_B, lane=1, agent_id="bob")

    eng = _engine(cfg, params)
    eng.submit(PROMPT_A, lane=0, agent_id="alice")
    eng.submit(PROMPT_B, lane=1, agent_id="bob")

    for op, n in ops:
        if op == "run":
            ref.run(n)
            eng.run(n)
        elif op == "hib" and eng.registry.get("alice").status == ACTIVE:
            eng.hibernate("alice")
        elif op == "wake" and eng.registry.get("alice").status == HIBERNATED:
            eng.wake("alice")  # async: commits at a later boundary
    if eng.registry.get("alice").status != ACTIVE:
        eng.wake("alice", wait=True)
    ref.run(4)
    eng.run(4)

    bob = next(m for m in eng.mains if m.agent_id == "bob")
    alice = next(m for m in eng.mains if m.agent_id == "alice")
    assert bob.tokens == ref.mains[1].tokens
    assert alice.tokens == ref.mains[0].tokens[: len(alice.tokens)]
    assert eng.stats["hibernates"] == eng.stats["wakes"]


# ---------------------------------------------------------------------------
# BatchServer park / unpark
# ---------------------------------------------------------------------------

def _server(cfg, params, n_lanes=2):
    return BatchServer(
        params, cfg, ByteTokenizer(cfg.vocab_size), n_lanes=n_lanes,
        capacity=128, sampling=SamplingParams(greedy=True),
    )


@pytest.mark.parametrize("pipeline", [False, True])
def test_server_park_unpark_stream_parity(setup, pipeline):
    cfg, params = setup
    ref = _server(cfg, params)
    ref.submit(PROMPT_A, max_new_tokens=20)
    ref_req = ref.run_until_done(pipeline=pipeline)[0]

    srv = _server(cfg, params)
    rid = srv.submit(PROMPT_A, max_new_tokens=20)
    for _ in range(6):
        srv.tick()
    assert srv.park(rid)
    assert srv.lanes == [None, None] and rid in srv.parked
    assert srv.store.tier_of(f"req{rid}") == "warm"
    rid2 = srv.submit(PROMPT_B, max_new_tokens=6)  # recycles the lane
    for _ in range(3):
        srv.tick()
    assert srv.unpark(rid)
    done = {r.rid: r for r in srv.run_until_done(pipeline=pipeline)}
    assert done[rid].tokens == ref_req.tokens  # bitwise continuation
    assert done[rid2].done
    assert f"req{rid}" not in srv.store  # snapshot dropped on resume


def test_server_cancel_parked_and_resuming(setup):
    cfg, params = setup
    srv = _server(cfg, params)
    rid = srv.submit(PROMPT_A, max_new_tokens=16)
    for _ in range(4):
        srv.tick()
    srv.park(rid)
    assert srv.cancel(rid)  # parked: snapshot dropped
    assert f"req{rid}" not in srv.store and rid not in srv.parked

    rid2 = srv.submit(PROMPT_B, max_new_tokens=16)
    for _ in range(4):
        srv.tick()
    srv.park(rid2)
    srv.unpark(rid2)
    assert srv.cancel(rid2)  # mid-resume: ticket abandoned, snapshot dropped
    assert f"req{rid2}" not in srv.store and not srv._resume
    # cancelled requests stay observable (ISSUE 9): done, status recorded,
    # counted in stats, present in finished — they no longer vanish
    done = {r.rid: r for r in srv.finished}
    assert set(done) == {rid, rid2}
    assert all(r.done and r.status == "cancelled" for r in done.values())
    assert srv.stats["cancelled"] == 2
    assert {r.rid for r in srv.run_until_done()} == {rid, rid2}  # nothing NEW
