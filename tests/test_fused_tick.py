"""Fused-tick engine invariants (ISSUE 3 acceptance criteria):

* parity: N fused ticks produce the same tokens/cache state as N legacy
  per-step decodes (greedy sampling, fixed seed), for main AND side lanes;
* drain cadence does not change results (greedy);
* tick() issues exactly ONE jitted dispatch and ZERO blocking host syncs
  between drains when sync_every > 1;
* synapse_decode output matches between the Pallas kernel and the
  piece_attend (sharded) fallback.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import synapse as synapse_lib
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.core.router import CortexRouter
from repro.data.tokenizer import ByteTokenizer
from repro.models import attention, cache as cache_lib
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


def _engine(cfg, params, *, sync_every=1, max_side=1, theta=2.0, side_max_steps=64):
    prism = Prism(params, cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    return CortexEngine(
        prism, tok, n_main=1, max_side=max_side, main_capacity=128,
        side_max_steps=side_max_steps, inject_tokens=8, theta=theta,
        sampling=SamplingParams(greedy=True), sync_every=sync_every,
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def test_fused_tick_matches_legacy_main_decode(setup):
    """Greedy main-lane stream == reference prefill + per-step decode_step
    chain (the legacy two-dispatch formulation), including the cache."""
    cfg, params = setup
    eng = _engine(cfg, params, sync_every=4)
    prompt = "the quick brown fox"
    m = eng.submit(prompt, lane=0)
    ids = list(m.tokens)
    n = 8
    eng.run(n)

    spec = model_lib.CacheSpec(kind="full", capacity=128)
    caches = model_lib.init_caches(cfg, 1, spec)
    toks = jnp.asarray([ids], jnp.int32)
    logits, _, caches = model_lib.prefill(params, cfg, {"tokens": toks}, caches, spec=spec)
    ref = list(ids)
    pos = len(ids)
    for _ in range(n):
        logits, _, caches = model_lib.decode_step(
            params, cfg,
            {"tokens": jnp.asarray([ref[-1]], jnp.int32), "positions": jnp.asarray([pos], jnp.int32)},
            caches, spec=spec,
        )
        ref.append(int(jnp.argmax(logits[0])))
        pos += 1

    assert m.tokens == ref
    # cache parity: same K/V prefix written
    eng_cache = eng.main_caches.groups[0]
    ref_cache = caches.groups[0]
    length = int(np.asarray(ref_cache.length)[0, 0])
    assert int(np.asarray(eng_cache.length)[0, 0]) == length
    np.testing.assert_allclose(
        np.asarray(eng_cache.k[:, :, :length], np.float32),
        np.asarray(ref_cache.k[:, :, :length], np.float32),
        rtol=1e-5, atol=1e-5,
    )


def test_drain_cadence_is_invisible_greedy(setup):
    """sync_every=1 vs sync_every=4 must produce identical main streams."""
    cfg, params = setup
    outs = []
    for sync_every in (1, 4):
        eng = _engine(cfg, params, sync_every=sync_every)
        m = eng.submit("parity probe", lane=0)
        eng.run(8)
        outs.append(list(m.tokens))
    assert outs[0] == outs[1]


def test_fused_tick_matches_legacy_side_decode(setup):
    """Side-lane stream (teacher-forced prompt then free-running greedy) ==
    reference decode_step chain over the spawn-time synapse snapshot."""
    cfg, params = setup
    eng = _engine(cfg, params, sync_every=1, side_max_steps=64)
    eng.submit("context context [TASK: think hard] tail", lane=0)
    s = next(s for s in eng.sides if s.active)
    # deep copy: the live buffers are donated away by subsequent ticks
    snapshot = jax.tree.map(lambda a: jnp.array(a, copy=True), eng.side_caches)
    prompt_ids = list(s.tokens)
    pos0 = s.position
    n = len(prompt_ids) + 6  # cover teacher forcing AND free generation
    eng.run(n)

    caches = snapshot
    plen = len(prompt_ids)
    ref_generated = []
    last = prompt_ids[-1]
    for t in range(n):
        in_tok = prompt_ids[t] if t < plen else last
        logits, _, caches = model_lib.decode_step(
            params, cfg,
            {"tokens": jnp.asarray([in_tok], jnp.int32),
             "positions": jnp.asarray([pos0 + t], jnp.int32)},
            caches, spec=eng.side_spec,
        )
        samp = int(jnp.argmax(logits[0]))
        if t >= plen - 1:
            ref_generated.append(samp)
            last = samp
    assert s.tokens[plen:] == ref_generated[: len(s.tokens) - plen]
    assert len(s.tokens) > plen  # the stream actually generated tokens


def test_tick_is_one_dispatch_zero_syncs(setup):
    """Acceptance: with sync_every > 1, tick() = exactly one jitted dispatch
    and no blocking host transfer; drain happens every sync_every ticks."""
    cfg, params = setup
    eng = _engine(cfg, params, sync_every=4)
    eng.submit("dispatch counting", lane=0)
    for _ in range(4):  # warm the SINGLE-tick jit + a drain (run() would
        eng.tick()      # warm the scanned macro path instead)
    base = dict(eng.stats)
    # transfer_guard makes the "no blocking transfer" invariant real: any
    # implicit device<->host traffic inside tick() raises, independent of
    # the engine's self-reported counters.
    with jax.transfer_guard("disallow"):
        for i in range(3):  # ticks 1..3 of a window: no drain
            eng.tick()
    assert eng.stats["tick_dispatches"] - base["tick_dispatches"] == 3
    assert eng.stats["host_syncs"] == base["host_syncs"]
    assert eng.stats["drains"] == base["drains"]
    assert eng.stats["aux_dispatches"] == base["aux_dispatches"]
    eng.tick()  # 4th tick closes the window
    assert eng.stats["tick_dispatches"] - base["tick_dispatches"] == 4
    assert eng.stats["drains"] == base["drains"] + 1
    assert eng.stats["host_syncs"] == base["host_syncs"] + 1


def test_lifecycle_with_batched_drain(setup):
    """Spawn + merge still work when control runs at drain granularity."""
    cfg, params = setup
    eng = _engine(cfg, params, sync_every=4, max_side=2, theta=-1.0, side_max_steps=6)
    eng.submit("hello [TASK: verify this claim] world", lane=0)
    eng.run(48)  # prompt forcing (~25 ticks) + 6 generated + drain slack
    events = [e["event"] for e in eng.history]
    assert "spawn" in events
    merge = next(e for e in eng.history if e["event"] == "merge")
    assert merge["accepted"] is True  # theta = -1 accepts everything


def test_synapse_decode_pallas_matches_piece():
    """The Pallas attend (default) and piece_attend (sharded fallback) give
    the same decode output and cache update."""
    cfg = _cfg()
    params = attention.attn_init(jax.random.key(0), cfg, jnp.float32)
    B, K, W, J = 3, 16, 8, 4
    cache = cache_lib.init_synapse_cache(cfg, B, K, W, J, jnp.float32)
    ks = jax.random.split(jax.random.key(1), 6)
    cache = dataclasses.replace(
        cache,
        lm_k=jax.random.normal(ks[0], cache.lm_k.shape),
        lm_v=jax.random.normal(ks[1], cache.lm_v.shape),
        lm_score=jax.random.uniform(ks[2], cache.lm_score.shape),
        lm_count=jnp.asarray([0, 5, K], jnp.int32),
        win_k=jax.random.normal(ks[3], cache.win_k.shape),
        win_v=jax.random.normal(ks[4], cache.win_v.shape),
        win_count=jnp.asarray([2, W, W + 3], jnp.int32),
        length=jnp.asarray([2, W + 5, K + W + 3], jnp.int32),
    )
    x = jax.random.normal(ks[5], (B, 1, cfg.d_model))
    positions = jnp.asarray([3, 40, 90], jnp.int32)
    outs = {}
    for impl in ("pallas", "piece"):
        policy = synapse_lib.SynapsePolicy(attend_impl=impl)
        y, new_cache, stats = synapse_lib.synapse_decode(
            params, cfg, x, cache, positions, policy
        )
        outs[impl] = (y, new_cache, stats)
    y_p, c_p, st_p = outs["pallas"]
    y_j, c_j, st_j = outs["piece"]
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_j), rtol=1e-5, atol=1e-5)
    for leaf_p, leaf_j in zip(jax.tree.leaves(c_p), jax.tree.leaves(c_j)):
        np.testing.assert_allclose(
            np.asarray(leaf_p, np.float32), np.asarray(leaf_j, np.float32),
            rtol=1e-5, atol=1e-5,
        )
    np.testing.assert_allclose(
        np.asarray(st_p["attn_mass_landmarks"]), np.asarray(st_j["attn_mass_landmarks"]),
        rtol=1e-5, atol=1e-5,
    )


def test_router_feed_incremental_exactly_once():
    r = CortexRouter()
    assert r.feed("a", "xy [TAS") == []
    trig = r.feed("a", "K: joined] z")
    assert [t.kind for t in trig] == ["task"]
    assert trig[0].payload == "joined"
    assert r.feed("a", "") == []          # tail rescan must not re-fire
    assert r.feed("a", " more text") == []
    trig = r.feed("a", " [DONE]")
    assert [t.kind for t in trig] == ["done"]
