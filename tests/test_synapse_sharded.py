"""One-hot cache primitives + piece_attend == reference attend (the §Perf
flash-decode path must be numerically identical on one device), plus the
scoped ShardContext API that replaced the old set_shard_axis module global
(ISSUE 6): entering/exiting a context must never leak into later traces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import synapse_sharded as sh
from repro.models.attention import decode_attend


def test_onehot_write_read_roundtrip():
    buf = jnp.zeros((3, 8, 2, 4))
    new = jnp.ones((3, 2, 4)) * jnp.arange(1, 4)[:, None, None]
    slot = jnp.asarray([0, 3, 7])
    out = sh.onehot_write(buf, slot, new)
    back = sh.onehot_read(out, slot)
    np.testing.assert_allclose(np.asarray(back), np.asarray(new))
    # untouched slots remain zero
    assert float(out.sum()) == float(new.sum())


def test_onehot_write_mask():
    buf = jnp.zeros((2, 4))
    out = sh.onehot_write(buf, jnp.asarray([1, 2]), jnp.asarray([5.0, 7.0]),
                          mask=jnp.asarray([True, False]))
    assert float(out[0, 1]) == 5.0 and float(out[1, 2]) == 0.0


def test_piece_attend_matches_decode_attend():
    B, H, Hkv, D = 2, 8, 4, 32
    ks = jax.random.split(jax.random.key(0), 7)
    q = jax.random.normal(ks[0], (B, H, D))
    sizes = [16, 8, 4]
    pieces, valids = [], []
    for i, T in enumerate(sizes):
        k = jax.random.normal(ks[1 + i], (B, T, Hkv, D))
        v = jax.random.normal(ks[4 + i], (B, T, Hkv, D))
        pieces.append((k, v))
        valids.append(jax.random.bernoulli(ks[i], 0.8, (B, T)).at[:, 0].set(True))
    scale = 1.0 / (D ** 0.5)
    out, masses = sh.piece_attend(q, pieces, valids, scale)

    keys = jnp.concatenate([k for k, _ in pieces], axis=1)
    vals = jnp.concatenate([v for _, v in pieces], axis=1)
    valid = jnp.concatenate(valids, axis=1)
    out_ref, mass_ref = decode_attend(q, keys, vals, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(masses, 1)), np.asarray(mass_ref), rtol=1e-5, atol=1e-5
    )


def test_token_sharding_scope_is_leak_proof():
    """The context manager restores the previous placement on exit AND on
    error — the failure mode of the old module global (one test setting it
    poisoned every later trace in the interpreter)."""
    assert sh.get_shard_axis() is None
    with sh.token_sharding("model", mesh="fake-mesh"):
        assert sh.get_shard_axis() == "model"
        assert sh.current_context().mesh == "fake-mesh"
        with sh.token_sharding(None):  # nested scopes override and restore
            assert sh.get_shard_axis() is None
        assert sh.get_shard_axis() == "model"
    assert sh.get_shard_axis() is None
    with pytest.raises(RuntimeError):
        with sh.token_sharding("model"):
            raise RuntimeError("boom")
    assert sh.get_shard_axis() is None


def test_explicit_ctx_overrides_ambient_scope():
    """Callers that thread a ShardContext (the engine's policy path) are
    immune to whatever ambient scope is live: an explicit local ctx under a
    sharded scope still takes the exact-scatter fast path."""
    buf = jnp.zeros((3, 8, 2, 4))
    new = jnp.ones((3, 2, 4))
    slot = jnp.asarray([0, 3, 7])
    local = sh.ShardContext()
    with sh.token_sharding("model", mesh="fake-mesh"):
        out = sh.onehot_write(buf, slot, new, ctx=local)
        back = sh.onehot_read(out, slot, ctx=local)
    np.testing.assert_allclose(np.asarray(back), np.asarray(new))


def test_onehot_sharded_formulation_matches_scatter():
    """The one-hot select/contract (used when a token axis is live) equals
    the plain scatter/gather fast path bit-for-bit on in-bounds slots —
    onehot needs no collective, so an axis-bearing ctx without a mesh
    exercises it on one device."""
    key = jax.random.key(3)
    buf = jax.random.normal(key, (4, 8, 2, 4))
    new = jax.random.normal(jax.random.key(4), (4, 2, 4))
    slot = jnp.asarray([0, 5, 7, 2])
    mask = jnp.asarray([True, False, True, True])
    oh_ctx = sh.ShardContext(axis="model")  # no mesh: onehot is collective-free
    a = sh.onehot_write(buf, slot, new, mask=mask)
    b = sh.onehot_write(buf, slot, new, mask=mask, ctx=oh_ctx)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(sh.onehot_read(buf, slot)),
        np.asarray(sh.onehot_read(buf, slot, ctx=oh_ctx)),
    )


def test_piece_attend_requires_mesh_with_axis():
    q = jnp.zeros((1, 4, 8))
    k = jnp.zeros((1, 4, 2, 8))
    valid = jnp.ones((1, 4), bool)
    with pytest.raises(ValueError, match="no mesh"):
        sh.piece_attend(q, [(k, k)], [valid], 0.5,
                        ctx=sh.ShardContext(axis="model"))


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >=2 devices")
def test_piece_attend_sharded_matches_local():
    """The psum flash-decode over a token-sharded mesh matches the local
    fused path (rtol: the combine reorders the softmax reductions)."""
    mesh = jax.make_mesh((2,), ("model",))
    B, H, Hkv, D = 2, 4, 2, 16
    ks = jax.random.split(jax.random.key(7), 5)
    q = jax.random.normal(ks[0], (B, H, D))
    pieces, valids = [], []
    for i, T in enumerate((8, 4)):
        k = jax.random.normal(ks[1 + i], (B, T, Hkv, D))
        v = jax.random.normal(ks[3 + i], (B, T, Hkv, D))
        pieces.append((k, v))
        valids.append(jnp.ones((B, T), bool).at[:, -1].set(i == 0))
    scale = 1.0 / (D ** 0.5)
    out_l, mass_l = sh.piece_attend(q, pieces, valids, scale)
    out_s, mass_s = sh.piece_attend(
        q, pieces, valids, scale, ctx=sh.ShardContext("model", mesh)
    )
    np.testing.assert_allclose(np.asarray(out_l), np.asarray(out_s), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(mass_l, 1)),
        np.asarray(jnp.concatenate(mass_s, 1)), rtol=1e-5, atol=1e-6,
    )
