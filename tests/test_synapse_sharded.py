"""One-hot cache primitives + piece_attend == reference attend (the §Perf
flash-decode path must be numerically identical on one device)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import synapse_sharded as sh
from repro.models.attention import decode_attend


def test_onehot_write_read_roundtrip():
    buf = jnp.zeros((3, 8, 2, 4))
    new = jnp.ones((3, 2, 4)) * jnp.arange(1, 4)[:, None, None]
    slot = jnp.asarray([0, 3, 7])
    out = sh.onehot_write(buf, slot, new)
    back = sh.onehot_read(out, slot)
    np.testing.assert_allclose(np.asarray(back), np.asarray(new))
    # untouched slots remain zero
    assert float(out.sum()) == float(new.sum())


def test_onehot_write_mask():
    buf = jnp.zeros((2, 4))
    out = sh.onehot_write(buf, jnp.asarray([1, 2]), jnp.asarray([5.0, 7.0]),
                          mask=jnp.asarray([True, False]))
    assert float(out[0, 1]) == 5.0 and float(out[1, 2]) == 0.0


def test_piece_attend_matches_decode_attend():
    B, H, Hkv, D = 2, 8, 4, 32
    ks = jax.random.split(jax.random.key(0), 7)
    q = jax.random.normal(ks[0], (B, H, D))
    sizes = [16, 8, 4]
    pieces, valids = [], []
    for i, T in enumerate(sizes):
        k = jax.random.normal(ks[1 + i], (B, T, Hkv, D))
        v = jax.random.normal(ks[4 + i], (B, T, Hkv, D))
        pieces.append((k, v))
        valids.append(jax.random.bernoulli(ks[i], 0.8, (B, T)).at[:, 0].set(True))
    scale = 1.0 / (D ** 0.5)
    out, masses = sh.piece_attend(q, pieces, valids, scale)

    keys = jnp.concatenate([k for k, _ in pieces], axis=1)
    vals = jnp.concatenate([v for _, v in pieces], axis=1)
    valid = jnp.concatenate(valids, axis=1)
    out_ref, mass_ref = decode_attend(q, keys, vals, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(masses, 1)), np.asarray(mass_ref), rtol=1e-5, atol=1e-5
    )
