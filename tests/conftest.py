import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.models import model as model_lib


@pytest.fixture(autouse=True)
def _no_act_sharding():
    # tests run on the single CPU device; disable launch-time constraints
    model_lib.set_activation_sharding(None)
    yield
    model_lib.set_activation_sharding(None)


def reduced_fp32(arch: str):
    return dataclasses.replace(get_config(arch, reduced=True), compute_dtype="float32")


def tiny_params(arch: str, seed: int = 0):
    cfg = reduced_fp32(arch)
    return cfg, model_lib.init_params(jax.random.key(seed), cfg)
