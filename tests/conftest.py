import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.models import model as model_lib


def hypothesis_tools():
    """(given, settings, st) — real hypothesis when installed, else stubs
    that mark only the property tests skipped so the plain tests in the
    same module keep running (the container may lack hypothesis)."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*a, **k):
            return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

        def settings(*a, **k):
            return lambda f: f

        return given, settings, _Strategies()


@pytest.fixture(autouse=True)
def _no_act_sharding():
    # tests run on the single CPU device; disable launch-time constraints
    model_lib.set_activation_sharding(None)
    yield
    model_lib.set_activation_sharding(None)


def reduced_fp32(arch: str):
    return dataclasses.replace(get_config(arch, reduced=True), compute_dtype="float32")


def tiny_params(arch: str, seed: int = 0):
    cfg = reduced_fp32(arch)
    return cfg, model_lib.init_params(jax.random.key(seed), cfg)
