"""Serving front-end: admission, fairness, streaming, SLOs (ISSUE 9).

The contract this suite pins down:

* FAIRNESS — `FairQueue` admits in weighted-fair order: with tenants at
  4:1 weights and equal budgets, admitted token budgets track the weight
  ratio over any saturated prefix; higher priority classes preempt WFQ
  order; and NO request waits more than ``starvation_rounds`` admission
  decisions, whatever its tenant's weight or its priority (the starvation
  bound), with promotions counted;
* ADMISSION — submits past ``max_queue`` raise :class:`AdmissionError`
  and are counted per tenant (explicit back-pressure, never silent drop);
  admissions land only through the backends' boundary hooks;
* STREAMING — a request's :class:`TokenStream` accumulates text that is
  bitwise equal to the backend's final ``decode(tokens)`` — on the
  BatchServer path (per-step chunks, pipelined) and the engine path
  (per-drain chunks, flush tail delivered at retirement) — and handles
  can be consumed from another thread while the pump runs;
* CANCELLATION — queued and running requests cancel observably: the
  stream closes with status "cancelled";
* SLOs — :meth:`ServingFrontend.metrics` reports per-request TTFT /
  queue-wait / TPOT, per-tenant token shares summing to 1, fairness
  counters, and p50/p99 tick latency — the exact section
  benchmarks/bench_serving.py records into BENCH_throughput.json.
"""
import dataclasses
import threading

import jax
import pytest

from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.frontend import (
    AdmissionError,
    FairQueue,
    FrontRequest,
    ServingFrontend,
    TokenStream,
)
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _req(rid, tenant, priority=0, budget=10):
    return FrontRequest(rid, "p", tenant, priority, budget, None, TokenStream(rid))


# ---------------------------------------------------------------------------
# FairQueue units (no model)
# ---------------------------------------------------------------------------

def test_fair_queue_weighted_shares_track_weights():
    # bound high enough that aging never fires: pure WFQ order under a
    # standing backlog (the starvation bound gets its own test below)
    fq = FairQueue({"a": 4.0, "b": 1.0}, starvation_rounds=1000)
    for i in range(40):
        fq.push(_req(100 + i, "a"))
        fq.push(_req(200 + i, "b"))
    admitted = [fq.pop().tenant for _ in range(40)]
    # over any saturated prefix the 4:1 ratio holds to within one quantum
    for n in (5, 10, 20, 40):
        a = admitted[:n].count("a")
        assert abs(a / n - 0.8) <= 1 / n + 1e-9, f"prefix {n}: {a}/{n}"


def test_fair_queue_priority_preempts_wfq():
    fq = FairQueue({"a": 4.0, "b": 1.0})
    for i in range(4):
        fq.push(_req(10 + i, "a", priority=0))
    fq.push(_req(99, "b", priority=5))
    assert fq.pop().rid == 99  # high class wins despite b's 1/5 weight


def test_fair_queue_starvation_bound_holds():
    fq = FairQueue({"hog": 100.0, "tiny": 0.01}, starvation_rounds=8)
    fq.push(_req(1, "tiny", priority=-1, budget=10))
    for i in range(200):
        fq.push(_req(100 + i, "hog", priority=3, budget=10))
    waited = None
    for n in range(1, 50):
        if fq.pop().rid == 1:
            waited = n
            break
    # despite a 10000x weight disadvantage AND a lower priority class, the
    # request is admitted within the bound (+1: the bound counts decisions
    # after enqueue)
    assert waited is not None and waited <= fq.starvation_rounds + 1
    assert fq.starvation_promotions == 1


def test_fair_queue_idle_tenant_banks_no_credit():
    fq = FairQueue({"a": 1.0, "b": 1.0})
    for i in range(10):
        fq.push(_req(i, "a"))
    for _ in range(10):
        fq.pop()  # a's vtime advances while b is idle
    fq.push(_req(50, "a"))
    fq.push(_req(51, "b"))
    # b returns from idle floored to the virtual floor: it gets NO credit for
    # the 10 admissions it sat out — both tenants are served within two pops
    # instead of b monopolizing ten in a row
    assert {fq.pop().rid, fq.pop().rid} == {50, 51}


def test_fair_queue_remove_and_len():
    fq = FairQueue()
    fq.push(_req(1, "t"))
    fq.push(_req(2, "t"))
    assert len(fq) == 2
    assert fq.remove(1).rid == 1
    assert fq.remove(1) is None
    assert len(fq) == 1 and fq.pop().rid == 2


# ---------------------------------------------------------------------------
# front-end over BatchServer
# ---------------------------------------------------------------------------

def _frontend(cfg, params, **kw):
    srv = BatchServer(params, cfg, ByteTokenizer(cfg.vocab_size), n_lanes=2,
                      capacity=128, sampling=SamplingParams(greedy=True))
    return ServingFrontend(srv, **kw)


def test_batch_stream_bitwise_and_slo_metrics(setup):
    cfg, params = setup
    fe = _frontend(cfg, params, tenants={"gold": 4.0, "free": 1.0})
    tok = fe.backend.tok
    streams = {}
    for i in range(4):
        tenant = "gold" if i % 2 == 0 else "free"
        streams[i] = fe.submit(f"prompt number {i} é∑", tenant=tenant,
                               max_new_tokens=16)
    fe.serve(pipeline=True)
    finished = {r.rid: r for r in fe.backend.finished}
    for s in streams.values():
        assert s.done and s.status == "ok"
        req = finished[fe.requests[s.rid].backend_id]
        # streamed chunks concatenate to the one-shot decode, bitwise
        assert s.text == req.text == tok.decode(req.tokens[req.prompt_len:])
    m = fe.metrics()
    assert m["completed"] == 4 and m["backend"] == "batch"
    for row in m["requests"]:
        assert row["ttft_s"] is not None and row["ttft_s"] >= 0
        assert row["queue_wait_s"] is not None
        assert row["tokens_out"] == 16
    shares = {t: v["token_share"] for t, v in m["tenants"].items()}
    assert abs(sum(shares.values()) - 1.0) < 1e-9
    assert m["tick_latency_s"]["n"] > 0
    assert m["tick_latency_s"]["p99"] >= m["tick_latency_s"]["p50"] > 0
    assert m["fairness"]["admission_rounds"] == 4


def test_batch_stream_consumed_from_other_thread(setup):
    cfg, params = setup
    fe = _frontend(cfg, params)
    s = fe.submit("threaded stream ∑", max_new_tokens=12)
    got = []
    t = threading.Thread(target=lambda: got.extend(s))
    t.start()
    fe.serve()
    t.join(timeout=30)
    assert not t.is_alive()
    assert "".join(got) == s.text and s.done


def test_batch_cancel_queued_and_running(setup):
    cfg, params = setup
    fe = _frontend(cfg, params)  # 2 lanes
    s = [fe.submit(f"cancel target {i}", max_new_tokens=32) for i in range(3)]
    fe._admit_batch()  # boundary hook: fills both lanes, rid 3 stays queued
    assert fe.cancel(3)  # queued: closes immediately
    assert s[2].done and s[2].status == "cancelled"
    assert fe.cancel(1)  # running: BatchServer.cancel -> tap closes stream
    assert s[0].done and s[0].status == "cancelled"
    assert not fe.cancel(1)  # already terminal
    fe.serve()
    assert s[1].done and s[1].status == "ok"
    m = fe.metrics()
    statuses = sorted(r["status"] for r in m["requests"])
    assert statuses == ["cancelled", "cancelled", "ok"]
    assert fe.backend.stats["cancelled"] == 1  # only the running one reached it


def test_admission_error_on_full_queue(setup):
    cfg, params = setup
    fe = _frontend(cfg, params, max_queue=2)
    fe.submit("a", tenant="t")
    fe.submit("b", tenant="t")
    with pytest.raises(AdmissionError):
        fe.submit("c", tenant="t")
    assert fe.metrics()["tenants"]["t"]["rejected"] == 1
    fe.serve()  # the two admitted ones still complete


# ---------------------------------------------------------------------------
# front-end over CortexEngine
# ---------------------------------------------------------------------------

def test_engine_stream_bitwise_and_window_granularity(setup):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=2, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=True,
    )
    fe = ServingFrontend(eng, tenants={"gold": 4.0, "free": 1.0})
    a = fe.submit("engine prompt é∑ one", tenant="gold", max_new_tokens=10)
    b = fe.submit("engine prompt two", tenant="free", max_new_tokens=10)
    fe.serve()
    for s, rid in ((a, 1), (b, 2)):
        assert s.done and s.status == "ok"
        req = fe.requests[rid]
        rec = eng.registry.get(req.backend_id)
        view = next(m for m in eng.mains if m.agent_id == req.backend_id)
        assert not view.active  # retired at a boundary
        gen = view.tokens[view.prompt_len:]
        # stream text == final text minus prompt == one-shot decode, bitwise
        assert s.text == view.text[len(req.prompt):] == tok.decode(gen)
        # completion is window-granular: the budget is met, and the overshoot
        # is bounded by the pipelined windows in flight per serve chunk
        assert req.max_new_tokens <= req.tokens_out
        assert req.tokens_out <= req.max_new_tokens + 8 * eng.sync_every
    m = fe.metrics()
    assert m["backend"] == "engine" and m["completed"] == 2
    assert m["tick_latency_s"]["n"] > 0
    for row in m["requests"]:
        assert row["ttft_s"] is not None and row["tpot_s"] is not None


def test_engine_admission_reuses_freed_lane(setup):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=2, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=True,
    )
    fe = ServingFrontend(eng, tenants={"t": 1.0})
    streams = [fe.submit(f"queued req {i}", tenant="t", max_new_tokens=8)
               for i in range(4)]  # 4 requests, 2 river lanes
    fe.serve()
    assert all(s.done and s.status == "ok" for s in streams)
    # every admission + retirement happened at a boundary inside run();
    # 4 requests flowed through 2 lanes with no manual lane management
    assert fe.metrics()["fairness"]["admission_rounds"] == 4
    assert fe.pending() == 0


def test_engine_cancel_running_at_boundary(setup):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=2, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=True,
    )
    fe = ServingFrontend(eng, tenants={"t": 1.0})
    s = fe.submit("long running request", tenant="t", max_new_tokens=10_000)
    eng.run(4)  # admit + first window
    assert fe.cancel(1)
    eng.run(8)  # next boundary honors the cancel
    assert s.done and s.status == "cancelled"
    assert fe.pending() == 0
