"""Serving front-end: admission, fairness, streaming, SLOs (ISSUE 9).

The contract this suite pins down:

* FAIRNESS — `FairQueue` admits in weighted-fair order: with tenants at
  4:1 weights and equal budgets, admitted token budgets track the weight
  ratio over any saturated prefix; higher priority classes preempt WFQ
  order; and NO request waits more than ``starvation_rounds`` admission
  decisions, whatever its tenant's weight or its priority (the starvation
  bound), with promotions counted;
* ADMISSION — submits past ``max_queue`` raise :class:`AdmissionError`
  and are counted per tenant (explicit back-pressure, never silent drop);
  admissions land only through the backends' boundary hooks;
* STREAMING — a request's :class:`TokenStream` accumulates text that is
  bitwise equal to the backend's final ``decode(tokens)`` — on the
  BatchServer path (per-step chunks, pipelined) and the engine path
  (per-drain chunks, flush tail delivered at retirement) — and handles
  can be consumed from another thread while the pump runs;
* CANCELLATION — queued and running requests cancel observably: the
  stream closes with status "cancelled";
* SLOs — :meth:`ServingFrontend.metrics` reports per-request TTFT /
  queue-wait / TPOT, per-tenant token shares summing to 1, fairness
  counters, and p50/p99 tick latency — the exact section
  benchmarks/bench_serving.py records into BENCH_throughput.json.
"""
import dataclasses
import threading

import jax
import pytest

from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.frontend import (
    AdmissionError,
    FairQueue,
    FrontRequest,
    ServeStalled,
    ServingFrontend,
    TokenStream,
)
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _req(rid, tenant, priority=0, budget=10):
    return FrontRequest(rid, "p", tenant, priority, budget, None, TokenStream(rid))


# ---------------------------------------------------------------------------
# FairQueue units (no model)
# ---------------------------------------------------------------------------

def test_fair_queue_weighted_shares_track_weights():
    # bound high enough that aging never fires: pure WFQ order under a
    # standing backlog (the starvation bound gets its own test below)
    fq = FairQueue({"a": 4.0, "b": 1.0}, starvation_rounds=1000)
    for i in range(40):
        fq.push(_req(100 + i, "a"))
        fq.push(_req(200 + i, "b"))
    admitted = [fq.pop().tenant for _ in range(40)]
    # over any saturated prefix the 4:1 ratio holds to within one quantum
    for n in (5, 10, 20, 40):
        a = admitted[:n].count("a")
        assert abs(a / n - 0.8) <= 1 / n + 1e-9, f"prefix {n}: {a}/{n}"


def test_fair_queue_priority_preempts_wfq():
    fq = FairQueue({"a": 4.0, "b": 1.0})
    for i in range(4):
        fq.push(_req(10 + i, "a", priority=0))
    fq.push(_req(99, "b", priority=5))
    assert fq.pop().rid == 99  # high class wins despite b's 1/5 weight


def test_fair_queue_starvation_bound_holds():
    fq = FairQueue({"hog": 100.0, "tiny": 0.01}, starvation_rounds=8)
    fq.push(_req(1, "tiny", priority=-1, budget=10))
    for i in range(200):
        fq.push(_req(100 + i, "hog", priority=3, budget=10))
    waited = None
    for n in range(1, 50):
        if fq.pop().rid == 1:
            waited = n
            break
    # despite a 10000x weight disadvantage AND a lower priority class, the
    # request is admitted at EXACTLY the bound (ISSUE 10 bugfix: `rounds`
    # is incremented before the comparison, so the old `>` admitted one
    # decision late). Priority keeps normal order off `tiny` entirely, so
    # equality proves the promotion fired at the boundary and not before.
    assert waited == fq.starvation_rounds
    assert fq.starvation_promotions == 1


def test_fair_queue_starvation_boundary_exact():
    # pin the boundary from both sides: a request aged starvation_rounds - 1
    # is NOT promoted, the same request one decision later IS
    fq = FairQueue({"hog": 100.0, "tiny": 0.01}, starvation_rounds=4)
    fq.push(_req(1, "tiny", priority=-1))
    for i in range(20):
        fq.push(_req(100 + i, "hog", priority=3))
    for n in range(1, fq.starvation_rounds):
        assert fq.pop().rid != 1, f"promoted early at decision {n}"
    assert fq.starvation_promotions == 0
    assert fq.pop().rid == 1  # decision #starvation_rounds: promoted
    assert fq.starvation_promotions == 1


def test_percentile_nearest_rank_deterministic():
    from repro.serving.frontend import percentile

    # nearest-rank: rank = ceil(q/100 * n), 1-based. int(round(...)) used
    # banker's rounding, which picked rank 3 for p50 of an even-length
    # sample (round(1.5) == 2 -> index 2); the deterministic rule says 2.
    assert percentile([1, 2, 3, 4], 50) == 2.0
    assert percentile([1, 2, 3, 4], 99) == 4.0
    assert percentile([1, 2, 3, 4], 100) == 4.0
    assert percentile([1, 2], 50) == 1.0
    assert percentile([7], 99) == 7.0
    assert percentile([], 50) == 0.0
    # percentiles stay monotone in q
    s = [5, 1, 9, 3, 7, 2]
    qs = [0, 10, 25, 50, 75, 90, 99, 100]
    vals = [percentile(s, q) for q in qs]
    assert vals == sorted(vals)


def test_fair_queue_idle_tenant_banks_no_credit():
    fq = FairQueue({"a": 1.0, "b": 1.0})
    for i in range(10):
        fq.push(_req(i, "a"))
    for _ in range(10):
        fq.pop()  # a's vtime advances while b is idle
    fq.push(_req(50, "a"))
    fq.push(_req(51, "b"))
    # b returns from idle floored to the virtual floor: it gets NO credit for
    # the 10 admissions it sat out — both tenants are served within two pops
    # instead of b monopolizing ten in a row
    assert {fq.pop().rid, fq.pop().rid} == {50, 51}


def test_fair_queue_remove_and_len():
    fq = FairQueue()
    fq.push(_req(1, "t"))
    fq.push(_req(2, "t"))
    assert len(fq) == 2
    assert fq.remove(1).rid == 1
    assert fq.remove(1) is None
    assert len(fq) == 1 and fq.pop().rid == 2


# ---------------------------------------------------------------------------
# front-end over BatchServer
# ---------------------------------------------------------------------------

def _frontend(cfg, params, **kw):
    srv = BatchServer(params, cfg, ByteTokenizer(cfg.vocab_size), n_lanes=2,
                      capacity=128, sampling=SamplingParams(greedy=True))
    return ServingFrontend(srv, **kw)


def test_batch_stream_bitwise_and_slo_metrics(setup):
    cfg, params = setup
    fe = _frontend(cfg, params, tenants={"gold": 4.0, "free": 1.0})
    tok = fe.backend.tok
    streams = {}
    for i in range(4):
        tenant = "gold" if i % 2 == 0 else "free"
        streams[i] = fe.submit(f"prompt number {i} é∑", tenant=tenant,
                               max_new_tokens=16)
    fe.serve(pipeline=True)
    finished = {r.rid: r for r in fe.backend.finished}
    for s in streams.values():
        assert s.done and s.status == "ok"
        req = finished[fe.requests[s.rid].backend_id]
        # streamed chunks concatenate to the one-shot decode, bitwise
        assert s.text == req.text == tok.decode(req.tokens[req.prompt_len:])
    m = fe.metrics()
    assert m["completed"] == 4 and m["backend"] == "batch"
    for row in m["requests"]:
        assert row["ttft_s"] is not None and row["ttft_s"] >= 0
        assert row["queue_wait_s"] is not None
        assert row["tokens_out"] == 16
    shares = {t: v["token_share"] for t, v in m["tenants"].items()}
    assert abs(sum(shares.values()) - 1.0) < 1e-9
    assert m["tick_latency_s"]["n"] > 0
    assert m["tick_latency_s"]["p99"] >= m["tick_latency_s"]["p50"] > 0
    assert m["fairness"]["admission_rounds"] == 4


def test_batch_stream_consumed_from_other_thread(setup):
    cfg, params = setup
    fe = _frontend(cfg, params)
    s = fe.submit("threaded stream ∑", max_new_tokens=12)
    got = []
    t = threading.Thread(target=lambda: got.extend(s))
    t.start()
    fe.serve()
    t.join(timeout=30)
    assert not t.is_alive()
    assert "".join(got) == s.text and s.done


def test_batch_cancel_queued_and_running(setup):
    cfg, params = setup
    fe = _frontend(cfg, params)  # 2 lanes
    s = [fe.submit(f"cancel target {i}", max_new_tokens=32) for i in range(3)]
    fe._admit_batch()  # boundary hook: fills both lanes, rid 3 stays queued
    assert fe.cancel(3)  # queued: closes immediately
    assert s[2].done and s[2].status == "cancelled"
    assert fe.cancel(1)  # running: BatchServer.cancel -> tap closes stream
    assert s[0].done and s[0].status == "cancelled"
    assert not fe.cancel(1)  # already terminal
    fe.serve()
    assert s[1].done and s[1].status == "ok"
    m = fe.metrics()
    statuses = sorted(r["status"] for r in m["requests"])
    assert statuses == ["cancelled", "cancelled", "ok"]
    assert fe.backend.stats["cancelled"] == 1  # only the running one reached it


def test_admission_error_on_full_queue(setup):
    cfg, params = setup
    fe = _frontend(cfg, params, max_queue=2)
    fe.submit("a", tenant="t")
    fe.submit("b", tenant="t")
    with pytest.raises(AdmissionError):
        fe.submit("c", tenant="t")
    assert fe.metrics()["tenants"]["t"]["rejected"] == 1
    fe.serve()  # the two admitted ones still complete


def test_engine_tap_records_ttft_only_with_tokens(setup):
    # ISSUE 10 bugfix: a drain callback that delivered NO tokens for this
    # lane must not stamp t_first — TTFT means "a generated token exists"
    import types

    cfg, params = setup
    fe = _frontend(cfg, params)
    fe.backend.stats["ticks"] = 0  # the engine-style counter the tap samples
    req = _req(1, "t")
    fe.requests[1] = req
    fe.live["aid"] = req
    view = types.SimpleNamespace(agent_id="aid", kind="main")
    fe._engine_tap(view, "", [])
    assert req.t_first is None and req.tokens_out == 0
    fe._engine_tap(view, "xy", [1, 2])
    assert req.t_first is not None and req.tokens_out == 2
    t0 = req.t_first
    fe._engine_tap(view, "z", [3])
    assert req.t_first == t0  # first-token time never moves


def test_stream_backlog_overflow_flags_cancel(setup):
    # a consumer that stops reading past max_buffered_chars gets its
    # request flagged; the boundary cancel retires ONLY that request
    cfg, params = setup
    fe = _frontend(cfg, params)
    stalled = fe.submit("stalled consumer", max_new_tokens=64,
                        max_buffered_chars=4)
    healthy = fe.submit("healthy consumer", max_new_tokens=16)
    fe.serve()
    assert stalled.done and stalled.status == "cancelled"
    assert stalled.overflowed
    assert healthy.done and healthy.status == "ok"
    assert fe.backend.stats["cancelled"] == 1
    req = fe.requests[healthy.rid]
    fin = {r.rid: r for r in fe.backend.finished}[req.backend_id]
    # the healthy stream is untouched by the neighbor's overflow-cancel
    assert healthy.text == fin.text == \
        fe.backend.tok.decode(fin.tokens[fin.prompt_len:])


@pytest.mark.parametrize("pipeline", [False, True])
def test_admission_under_parked_and_resuming_lanes(setup, pipeline):
    """`_admit_batch`'s free-lane computation subtracts queued prompts AND
    in-flight resume tickets: a resuming lane must not be double-booked
    (over-admission), and a parked-without-resume lane must not be
    stranded (under-admission)."""
    cfg, params = setup
    srv = BatchServer(params, cfg, ByteTokenizer(cfg.vocab_size), n_lanes=2,
                      capacity=128, sampling=SamplingParams(greedy=True))
    fe = ServingFrontend(srv, tenants={"t": 1.0})
    s1 = fe.submit("park victim one", tenant="t", max_new_tokens=24)
    s2 = fe.submit("steady stream two", tenant="t", max_new_tokens=24)
    srv._admit()  # boundary: both admitted onto the two lanes
    assert fe.metrics()["fairness"]["admission_rounds"] == 2
    rid1 = fe.requests[s1.rid].backend_id

    # --- resuming: the freed lane is reserved by the resume ticket ------
    assert srv.park(rid1)
    assert srv.unpark(rid1)  # lane 0 free, but a resume ticket holds it
    s3 = fe.submit("queued three", tenant="t", max_new_tokens=12)
    admitted = fe._admit_batch()
    assert admitted == 0, "over-admitted into a lane reserved by a resume"
    assert len(srv.queue) == 0 and len(fe.fq) == 1

    # the resume lands at the next boundary, then the queued request takes
    # whatever frees up — nobody is stranded
    fe.serve(pipeline=pipeline)
    assert s1.done and s1.status == "ok"
    assert s2.done and s2.status == "ok"
    assert s3.done and s3.status == "ok"
    assert fe.pending() == 0 and len(fe.fq) == 0

    # --- parked without resume: the freed lane is genuinely free --------
    s4 = fe.submit("park victim four", tenant="t", max_new_tokens=48)
    s5 = fe.submit("waiter five", tenant="t", max_new_tokens=8)
    srv._admit()
    rid4 = fe.requests[s4.rid].backend_id
    assert srv.park(rid4)
    s6 = fe.submit("queued six", tenant="t", max_new_tokens=8)
    admitted = fe._admit_batch()
    assert admitted == 1, "stranded a free lane while a request was parked"
    srv._admit()  # prefill the admission the hook queued
    assert all(r is not None for r in srv.lanes)
    assert srv.unpark(rid4)
    fe.serve(pipeline=pipeline)
    for s in (s4, s5, s6):
        assert s.done and s.status == "ok", (s.rid, s.status)
    assert fe.pending() == 0


# ---------------------------------------------------------------------------
# front-end over CortexEngine
# ---------------------------------------------------------------------------

def test_engine_stream_bitwise_and_window_granularity(setup):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=2, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=True,
    )
    fe = ServingFrontend(eng, tenants={"gold": 4.0, "free": 1.0})
    a = fe.submit("engine prompt é∑ one", tenant="gold", max_new_tokens=10)
    b = fe.submit("engine prompt two", tenant="free", max_new_tokens=10)
    fe.serve()
    for s, rid in ((a, 1), (b, 2)):
        assert s.done and s.status == "ok"
        req = fe.requests[rid]
        rec = eng.registry.get(req.backend_id)
        view = next(m for m in eng.mains if m.agent_id == req.backend_id)
        assert not view.active  # retired at a boundary
        gen = view.tokens[view.prompt_len:]
        # stream text == final text minus prompt == one-shot decode, bitwise
        assert s.text == view.text[len(req.prompt):] == tok.decode(gen)
        # completion is window-granular: the budget is met, and the overshoot
        # is bounded by the pipelined windows in flight per serve chunk
        assert req.max_new_tokens <= req.tokens_out
        assert req.tokens_out <= req.max_new_tokens + 8 * eng.sync_every
    m = fe.metrics()
    assert m["backend"] == "engine" and m["completed"] == 2
    assert m["tick_latency_s"]["n"] > 0
    for row in m["requests"]:
        assert row["ttft_s"] is not None and row["tpot_s"] is not None


def test_engine_admission_reuses_freed_lane(setup):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=2, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=True,
    )
    fe = ServingFrontend(eng, tenants={"t": 1.0})
    streams = [fe.submit(f"queued req {i}", tenant="t", max_new_tokens=8)
               for i in range(4)]  # 4 requests, 2 river lanes
    fe.serve()
    assert all(s.done and s.status == "ok" for s in streams)
    # every admission + retirement happened at a boundary inside run();
    # 4 requests flowed through 2 lanes with no manual lane management
    assert fe.metrics()["fairness"]["admission_rounds"] == 4
    assert fe.pending() == 0


def test_engine_cancel_running_at_boundary(setup):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=2, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=True,
    )
    fe = ServingFrontend(eng, tenants={"t": 1.0})
    s = fe.submit("long running request", tenant="t", max_new_tokens=10_000)
    eng.run(4)  # admit + first window
    assert fe.cancel(1)
    eng.run(8)  # next boundary honors the cancel
    assert s.done and s.status == "cancelled"
    assert fe.pending() == 0


def test_serve_budget_raises_on_stuck_retirement(setup):
    # ISSUE 10 bugfix regression: serve() used to treat max_ticks as a
    # per-iteration cap on an unbounded `while pending()` loop — a lane
    # whose retire_main keeps refusing (side streams target it) spun
    # forever. Now the budget is total and exhaustion raises with the
    # stuck rids.
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=1, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=True, side_max_steps=10_000,
    )
    fe = ServingFrontend(eng, tenants={"t": 1.0})
    # the [TASK:] tag spawns a side targeting lane 0 at submit; with a
    # 10k-step side budget the lane's retirement is refused at every
    # boundary long past the request's own 4-token budget
    s = fe.submit("please [TASK: keep thinking] go", tenant="t",
                  max_new_tokens=4)
    with pytest.raises(ServeStalled) as exc:
        fe.serve(max_ticks=64)
    assert exc.value.stuck == [1]
    assert not s.done  # never mis-reported as complete
    assert fe.requests[1].tokens_out >= 4  # budget met, retirement refused
