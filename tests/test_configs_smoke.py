"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned config runs one forward AND one train step on CPU; output shapes
and finiteness asserted. Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model as model_lib
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import init_train_state, make_train_step

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    params = model_lib.init_params(jax.random.key(0), cfg)
    B, S = 2, 32
    if cfg.embed_inputs:
        inputs = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)}
    else:
        inputs = {"embeds": jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))}
    logits, aux = model_lib.forward(params, cfg, inputs)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch, reduced=True)
    state = init_train_state(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10)))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, DataConfig(seq_len=32, batch_size=2)).items()}
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state.step) == 1
    # params actually changed
    delta = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(new_state.params))
    )
    assert delta > 0.0


def test_exact_assigned_configs():
    """The full configs match the assignment table exactly."""
    rows = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
    }
    for arch, (L, d, h, kv, ff, v) in rows.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size) == (
            L, d, h, kv, ff, v,
        ), arch
    assert get_config("zamba2-1.2b").ssm_state_size == 64
    assert get_config("qwen3-moe-30b-a3b").n_experts == 128
    assert get_config("qwen3-moe-30b-a3b").experts_per_token == 8
    ds = get_config("deepseek-v2-236b")
    assert ds.kv_lora_rank == 512 and ds.n_experts == 160 and ds.experts_per_token == 6
    assert ds.n_shared_experts == 2 and ds.attn_kind == "mla"
    assert get_config("qwen2-vl-72b").rope_kind == "mrope"
    assert not get_config("hubert-xlarge").causal


def test_param_counts_plausible():
    """Analytic counts land near the advertised sizes."""
    approx = {
        "smollm-135m": (0.134e9, 0.35),
        "qwen3-8b": (8.2e9, 0.35),
        "qwen1.5-110b": (111e9, 0.25),
        "deepseek-v2-236b": (236e9, 0.35),
        "qwen3-moe-30b-a3b": (30.5e9, 0.35),
        "rwkv6-1.6b": (1.6e9, 0.5),
        "zamba2-1.2b": (1.2e9, 0.6),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_moe_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    assert active < cfg.param_count() * 0.25
    assert 2e9 < active < 5e9  # "A3B"
