"""Referential Injection (§3.6) + Validation Gate (§3.5)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import gate as gate_lib
from repro.core import injection
from repro.models import model as model_lib


def _setup(arch="qwen3-8b"):
    cfg = dataclasses.replace(get_config(arch, reduced=True), compute_dtype="float32")
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def test_injection_changes_output_only_for_accepted_lanes():
    cfg, params = _setup()
    B, S = 2, 16
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    spec = model_lib.CacheSpec(kind="full", capacity=S + 16)
    caches = model_lib.init_caches(cfg, B, spec)
    _, _, caches = model_lib.prefill(params, cfg, {"tokens": tok}, caches, spec=spec)

    thought = jax.random.randint(jax.random.key(2), (B, 4), 0, cfg.vocab_size)
    vpos = jnp.full((B,), S, jnp.int32)
    th_caches, th_hidden = injection.encode_thought_kv(params, cfg, thought, vpos)
    accept = jnp.asarray([True, False])
    injected = injection.inject(cfg, caches, th_caches, accept)

    # lane 0 grew by 4, lane 1 untouched
    lengths = np.asarray(injected.groups[0].length)  # [L, B]
    assert (lengths[:, 0] == S + 4).all()
    assert (lengths[:, 1] == S).all()

    # next decode differs on lane 0, identical on lane 1
    step_tok = jnp.zeros((B,), jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    lg_base, _, _ = model_lib.decode_step(
        params, cfg, {"tokens": step_tok, "positions": pos}, caches, spec=spec
    )
    lg_inj, _, _ = model_lib.decode_step(
        params, cfg, {"tokens": step_tok, "positions": pos}, injected, spec=spec
    )
    d0 = float(jnp.abs(lg_inj[0] - lg_base[0]).max())
    d1 = float(jnp.abs(lg_inj[1] - lg_base[1]).max())
    assert d0 > 1e-4, "accepted lane must feel the thought"
    assert d1 < 1e-6, "rejected lane must be untouched"


def test_injection_preserves_stream_positions():
    """The visible stream's positions are NOT shifted by injection — the
    thought lives at virtual positions (paper: 'non-intrusive')."""
    cfg, params = _setup()
    B, S = 1, 12
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    spec = model_lib.CacheSpec(kind="full", capacity=S + 16)
    caches = model_lib.init_caches(cfg, B, spec)
    _, _, caches = model_lib.prefill(params, cfg, {"tokens": tok}, caches, spec=spec)
    thought = jax.random.randint(jax.random.key(2), (B, 4), 0, cfg.vocab_size)
    vpos = jnp.full((B,), 1000, jnp.int32)  # clearly-virtual index
    th_caches, _ = injection.encode_thought_kv(params, cfg, thought, vpos)
    injected = injection.inject(cfg, caches, th_caches, jnp.asarray([True]))
    pos = np.asarray(injected.groups[0].pos)[0, 0]  # layer 0, lane 0
    assert (pos[:S] == np.arange(S)).all()          # stream untouched
    assert (pos[S : S + 4] == np.arange(1000, 1004)).all()  # virtual indices


def test_synapse_injection_slots():
    cfg, params = _setup()
    B, S = 1, 16
    spec = model_lib.CacheSpec(kind="synapse", n_landmarks=8, window=8, n_inject=4)
    caches = model_lib.init_caches(cfg, B, spec)
    thought = jax.random.randint(jax.random.key(2), (B, 3), 0, cfg.vocab_size)
    th_caches, _ = injection.encode_thought_kv(params, cfg, thought, jnp.full((B,), 50, jnp.int32))
    injected = injection.inject(cfg, caches, th_caches, jnp.asarray([True]))
    assert int(np.asarray(injected.groups[0].inj_count)[0, 0]) == 3
    # injected keys become visible to the next synapse decode step
    tok = jnp.zeros((B,), jnp.int32)
    lg0, _, _ = model_lib.decode_step(
        params, cfg, {"tokens": tok, "positions": jnp.zeros((B,), jnp.int32)}, caches, spec=spec
    )
    lg1, _, _ = model_lib.decode_step(
        params, cfg, {"tokens": tok, "positions": jnp.zeros((B,), jnp.int32)}, injected, spec=spec
    )
    assert float(jnp.abs(lg1 - lg0).max()) > 1e-5


def test_ssm_state_blend():
    cfg, params = _setup("rwkv6-1.6b")
    B, S = 1, 12
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    spec = model_lib.CacheSpec(kind="full", capacity=S)
    caches = model_lib.init_caches(cfg, B, spec)
    _, _, caches = model_lib.prefill(params, cfg, {"tokens": tok}, caches, spec=spec)
    thought = jax.random.randint(jax.random.key(2), (B, 4), 0, cfg.vocab_size)
    th_caches, _ = injection.encode_thought_kv(params, cfg, thought, jnp.zeros((B,), jnp.int32))
    injected = injection.inject(cfg, caches, th_caches, jnp.asarray([True]), beta=0.3)
    w0 = np.asarray(caches.groups[0].wkv)
    w1 = np.asarray(injected.groups[0].wkv)
    wt = np.asarray(th_caches.groups[0].wkv)
    np.testing.assert_allclose(w1, 0.7 * w0 + 0.3 * wt, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
def test_gate_eq2():
    h = jnp.asarray([[1.0, 0.0], [1.0, 0.0], [1.0, 0.0]])
    t = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [-1.0, 0.0]])
    accept, score = gate_lib.validate(h, t, theta=0.5)
    np.testing.assert_allclose(np.asarray(score), [1.0, 0.0, -1.0], atol=1e-6)
    assert np.asarray(accept).tolist() == [True, False, False]


def test_gate_scale_invariance():
    key = jax.random.key(0)
    h = jax.random.normal(key, (4, 32))
    t = jax.random.normal(jax.random.key(1), (4, 32))
    _, s1 = gate_lib.validate(h, t)
    _, s2 = gate_lib.validate(h * 100.0, t * 0.01)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)
