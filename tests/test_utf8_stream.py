"""Streamed-text UTF-8 integrity (ISSUE 9 bugfix).

The contract this suite pins down:

* DECODER — for ANY partition of a token-id sequence into chunks,
  ``"".join(feed(chunk) for chunk) + flush()`` is bitwise equal to the
  one-shot ``ByteTokenizer.decode`` — multi-byte codepoints split across
  chunk boundaries, invalid byte sequences (same maximal-subpart U+FFFD
  rules), and interleaved special ids included;
* PERSISTENCE — the decoder's only state is the buffered incomplete
  trailing sequence; exporting ``pending`` and ``restore``-ing it into a
  fresh decoder resumes the stream bitwise (what lets a hibernated agent
  survive a park/wake mid-codepoint);
* SERVER — after ``run_until_done`` (serial AND pipelined), every finished
  request satisfies ``req.text == tok.decode(req.tokens[prompt_len:])``
  bitwise. The tiny random-init model emits bytes >= 0x80 constantly, so
  this exercises exactly the per-token-decode corruption the old
  ``self.tok.decode([t])`` call site had;
* ENGINE — same identity at window granularity for main agents
  (``agent_text`` mid-flight, ``m.text`` after ``retire_main``), where a
  codepoint can split across a drain boundary.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import hypothesis_tools
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer, Utf8StreamDecoder
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer

MULTI = "héllo ∑ x² — 日本語 🚀 done"


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# decoder units (no model)
# ---------------------------------------------------------------------------

def test_decoder_every_split_point_bitwise():
    tok = ByteTokenizer()
    ids = tok.encode(MULTI)
    want = tok.decode(ids)
    for cut in range(len(ids) + 1):
        dec = tok.stream_decoder()
        got = dec.feed(ids[:cut]) + dec.feed(ids[cut:]) + dec.flush()
        assert got == want, f"split at {cut}"


def test_decoder_one_id_at_a_time():
    tok = ByteTokenizer()
    ids = tok.encode(MULTI, bos=True, eos=True)
    dec = tok.stream_decoder()
    got = "".join(dec.feed([i]) for i in ids) + dec.flush()
    assert got == tok.decode(ids)
    # and the old buggy shape really does differ on this input
    buggy = "".join(tok.decode([i]) for i in ids)
    assert buggy != got and "�" in buggy


@pytest.mark.parametrize("raw", [
    b"\xe2\x82",                  # truncated 3-byte sequence at EOS
    b"\xe2\x28\xa1",              # invalid continuation byte
    b"ok \xf0\x9f\x9a\x80 \xff end",  # lone invalid byte amid a valid emoji
    bytes(range(120, 256)),       # dense high-byte garbage
])
def test_decoder_invalid_bytes_match_oneshot(raw):
    tok = ByteTokenizer()
    ids = list(raw)
    want = tok.decode(ids)
    for size in (1, 2, 3, 5):
        dec = tok.stream_decoder()
        got = "".join(
            dec.feed(ids[i:i + size]) for i in range(0, len(ids), size)
        ) + dec.flush()
        assert got == want, f"chunk size {size}"


def test_decoder_skips_specials_mid_codepoint():
    tok = ByteTokenizer()
    rocket = list("🚀".encode("utf-8"))
    ids = rocket[:2] + [tok.eos_id, tok.pad_id] + rocket[2:]
    dec = tok.stream_decoder()
    got = dec.feed(ids[:3]) + dec.feed(ids[3:]) + dec.flush()
    assert got == tok.decode(ids) == "🚀"


def test_decoder_pending_export_restore_bitwise():
    tok = ByteTokenizer()
    ids = tok.encode(MULTI)
    for cut in range(len(ids) + 1):
        a = tok.stream_decoder()
        head = a.feed(ids[:cut])
        moved = tok.stream_decoder()
        moved.restore(a.pending)  # hibernate/crash-recovery path
        got = head + moved.feed(ids[cut:]) + moved.flush()
        assert got == tok.decode(ids), f"restore at {cut}"


def test_decoder_tail_peeks_without_consuming():
    tok = ByteTokenizer()
    dec = tok.stream_decoder()
    dec.feed(list("🚀".encode("utf-8"))[:2])  # half a codepoint buffered
    assert dec.tail() == "�" == dec.tail()  # idempotent peek
    assert dec.pending == bytes("🚀".encode("utf-8"))[:2]
    # the peek did not consume: completing the codepoint still works
    assert dec.feed(list("🚀".encode("utf-8"))[2:]) + dec.flush() == "🚀"


_given, _settings, _st = hypothesis_tools()


@_given(
    data=_st.lists(_st.integers(min_value=0, max_value=300), max_size=60),
    seed=_st.integers(min_value=0, max_value=2**31 - 1),
)
@_settings(max_examples=80, deadline=None)
def test_decoder_random_chunking_property(data, seed):
    tok = ByteTokenizer()
    rng = np.random.default_rng(seed)
    dec, out, i = tok.stream_decoder(), [], 0
    while i < len(data):
        step = int(rng.integers(1, 5))
        out.append(dec.feed(data[i:i + step]))
        i += step
    out.append(dec.flush())
    assert "".join(out) == tok.decode(data)


# ---------------------------------------------------------------------------
# server / engine integration: final text == one-shot decode, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pipeline", [False, True])
def test_server_text_equals_oneshot_decode(setup, pipeline):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    srv = BatchServer(params, cfg, tok, n_lanes=2, capacity=128,
                      sampling=SamplingParams(greedy=True))
    for p in (MULTI, "plain ascii prompt"):
        srv.submit(p, max_new_tokens=24)
    done = srv.run_until_done(pipeline=pipeline)
    assert len(done) == 2
    for req in done:
        gen = req.tokens[req.prompt_len:]
        assert req.text == tok.decode(gen)  # bitwise, ISSUE 9 contract
        assert any(0x80 <= t < 0x100 for t in gen), \
            "random model emitted no multi-byte leads; test lost its teeth"


@pytest.mark.parametrize("pipeline", [False, True])
def test_engine_text_equals_oneshot_decode(setup, pipeline):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=2, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=pipeline,
    )
    a = eng.submit(MULTI, lane=0, agent_id="utf8a")
    b = eng.submit("plain ascii prompt", lane=1, agent_id="utf8b")
    eng.run(13)  # mid-window on the serial path: pending bytes likely
    for m, want in ((a, MULTI), (b, "plain ascii prompt")):
        gen = m.tokens[m.prompt_len:]
        # agent_text folds the decoder's buffered tail in, so mid-flight
        # text matches the one-shot decode of everything generated so far
        assert eng.agent_text(m.agent_id) == want + tok.decode(gen)
    eng.retire_main(0)
    gen = a.tokens[a.prompt_len:]
    assert a.text == MULTI + tok.decode(gen)  # flush made it exact


def test_engine_hibernate_preserves_decoder_pending(setup):
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        Prism(params, cfg), tok, n_main=2, max_side=2, main_capacity=128,
        inject_tokens=8, theta=-1.0, sampling=SamplingParams(greedy=True),
        sync_every=4, pipeline=True,
    )
    m = eng.submit(MULTI, lane=0, agent_id="parked")
    eng.run(12)
    eng.hibernate("parked")
    assert eng.wake("parked")
    eng.run(12)
    rec = eng.registry.get("parked")
    view = eng.mains[rec.lane]
    gen = view.tokens[view.prompt_len:]
    # the stream picked up bitwise across the park/wake — a codepoint split
    # across the hibernation boundary still decodes exactly once
    assert eng.agent_text("parked") == MULTI + tok.decode(gen)
