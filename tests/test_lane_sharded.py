"""Lane-sharded macro ticks (ISSUE 6 acceptance criteria).

The contract this suite pins down, on a forced-multi-device CPU mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the multi-device
tests self-skip without it; the mesh-of-1 test always runs):

* PARITY — greedy token streams from the lane-sharded engine (side state
  split over the ``lane`` mesh axis, macro window under ``shard_map``) are
  BITWISE identical to the single-device engine across spawn/merge
  interleavings: main and side tokens, event history, merge verdicts;
* DISPATCH COUNT — ``run(n)`` still issues exactly ``ceil(n/sync_every)``
  fused dispatches under the mesh;
* ZERO HOST SYNCS — the sharded window runs under
  ``jax.transfer_guard("disallow")``: all state is committed to the mesh up
  front, nothing implicit crosses the host boundary;
* DONATION — the sharded donated dispatch shows no peak-cache doubling:
  cache totals equal the single-device engine (the replicated serving-weight
  copy is reported separately) and stay bit-stable over more windows;
* PLACEMENT — side leaves really are lane-sharded (local shard = S/n_dev),
  main leaves really are replicated.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from conftest import hypothesis_tools
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_lane_mesh
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams

N_DEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


def _engine(cfg, params, mesh, *, sync_every=4, max_side=8, theta=-1.0,
            side_max_steps=6, sampling=SamplingParams(greedy=True)):
    return CortexEngine(
        Prism(params, cfg), ByteTokenizer(cfg.vocab_size), n_main=1,
        max_side=max_side, main_capacity=128, side_max_steps=side_max_steps,
        inject_tokens=8, theta=theta, sampling=sampling,
        sync_every=sync_every, mesh=mesh,
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def pair(setup):
    """The same spawn/merge workload on an 8-device lane mesh and on the
    default single device (theta=-1 accepts merges, so side thoughts mutate
    the replicated main cache mid-run — parity must survive the full
    control plane crossing the shard boundary)."""
    cfg, params = setup
    lane = _engine(cfg, params, make_lane_mesh(8))
    ref = _engine(cfg, params, None)
    prompt = "hello [TASK: go] world"
    lane.submit(prompt, lane=0)
    ref.submit(prompt, lane=0)
    base = dict(lane.stats)
    lane.run(24)
    ref.run(24)
    return lane, ref, base


@needs_mesh
def test_lane_sharded_matches_single_device_bitwise(pair):
    lane, ref, _ = pair
    assert lane.mains[0].tokens == ref.mains[0].tokens
    for sl, sr in zip(lane.sides, ref.sides):
        assert sl.tokens == sr.tokens
    assert [(e["event"], e.get("accepted")) for e in lane.history] == \
           [(e["event"], e.get("accepted")) for e in ref.history]
    assert any(e["event"] == "merge" for e in lane.history)


@needs_mesh
def test_lane_dispatch_count_is_ceil(pair, setup):
    lane, _, base = pair
    assert lane.stats["tick_dispatches"] - base["tick_dispatches"] == 24 // 4
    # partial trailing windows on a fresh sharded engine
    cfg, params = setup
    eng = _engine(cfg, params, make_lane_mesh(8), theta=2.0)
    eng.submit("ceil probe", lane=0)
    for n in (8, 7, 3, 1):
        b = eng.stats["tick_dispatches"]
        eng.run(n)
        assert eng.stats["tick_dispatches"] - b == math.ceil(n / 4), n


@needs_mesh
def test_zero_host_syncs_inside_sharded_window(setup):
    """Everything the macro dispatch reads was committed to the mesh at
    admission/drain time, so the whole sharded window runs with transfers
    hard-disallowed — the invariant that makes lane scaling free of
    per-tick host chatter."""
    cfg, params = setup
    eng = _engine(cfg, params, make_lane_mesh(8), theta=2.0)
    m = eng.submit("transfer guard probe [TASK: think] x", lane=0)
    eng.run(8)  # warm both scan variants + drain to a boundary
    base = dict(eng.stats)
    n_tok = len(m.tokens)
    with jax.transfer_guard("disallow"):
        eng._dispatch_window(eng.sync_every)
    assert eng.stats["tick_dispatches"] - base["tick_dispatches"] == 1
    assert eng.stats["host_syncs"] == base["host_syncs"]
    eng.drain()
    assert eng.stats["host_syncs"] == base["host_syncs"] + 1
    assert len(m.tokens) == n_tok + eng.sync_every


@needs_mesh
def test_sharded_donation_no_peak_doubling(pair):
    """The sharded dispatch donates the TickState exactly like the
    single-device one: per-agent cache bytes match the reference engine
    (the lane engine additionally reports its replicated serving-weight
    copy — a real resident buffer on the mesh, counted separately), and
    more windows leave the footprint bit-stable."""
    lane, ref, _ = pair
    rl, rr = lane.memory_report(), ref.memory_report()
    assert rl["n_agents"] == rr["n_agents"]
    cache_l = rl["total_bytes"] - rl["serving_weight_bytes"]
    cache_r = rr["total_bytes"] - rr["serving_weight_bytes"]
    assert cache_l == cache_r
    lane.run(8)
    assert lane.memory_report()["total_bytes"] == rl["total_bytes"]


@needs_mesh
def test_side_state_is_lane_sharded(pair):
    """Placement, not just parity: each device holds S/n_dev side lanes
    (caches shard dim 1 — dim 0 is the stacked layer axis), while the main
    stream and the PRNG key are fully replicated."""
    lane, _, _ = pair
    S = lane.max_side
    n = 8
    tok_shard = lane.state.side_tok.addressable_shards[0].data
    assert tok_shard.shape == (S // n,)
    cache_leaf = jax.tree.leaves(lane.state.side_caches)[0]
    shard = cache_leaf.addressable_shards[0].data
    assert shard.shape[1] == cache_leaf.shape[1] // n
    assert lane.state.main_tok.sharding.is_fully_replicated
    assert lane.state.key.sharding.is_fully_replicated


@needs_mesh
def test_max_side_must_divide_lane_axis(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="multiple of the lane-axis"):
        _engine(cfg, params, make_lane_mesh(8), max_side=6)


def test_mesh_of_one_matches_plain_engine(setup):
    """A 1-device lane mesh exercises the whole sharded code path —
    shard_map wrap, spec trees, out_shardings, committed cursor resets —
    on any machine, and must be bitwise identical to the plain engine.
    (Tier-1 coverage for the lane path without forced devices.)"""
    cfg, params = setup
    lane = _engine(cfg, params, make_lane_mesh(1), max_side=2)
    ref = _engine(cfg, params, None, max_side=2)
    prompt = "mesh of one [TASK: go] probe"
    lane.submit(prompt, lane=0)
    ref.submit(prompt, lane=0)
    lane.run(12)
    ref.run(12)
    assert lane.mains[0].tokens == ref.mains[0].tokens
    for sl, sr in zip(lane.sides, ref.sides):
        assert sl.tokens == sr.tokens


@needs_mesh
def test_batch_server_lane_placement(setup):
    """The plain-serving baseline under the same mesh: per-request KV lanes
    spread over the lane axis, greedy outputs bitwise identical to the
    unsharded server."""
    from repro.serving.server import BatchServer

    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)

    def serve(mesh):
        srv = BatchServer(params, cfg, tok, n_lanes=8, capacity=128,
                          sampling=SamplingParams(greedy=True), seed=0, mesh=mesh)
        for i in range(6):
            srv.submit(f"request {i}", max_new_tokens=12)
        done = srv.run_until_done()
        return sorted((r.rid, tuple(r.tokens)) for r in done)

    assert serve(make_lane_mesh(8)) == serve(None)


# ---------------------------------------------------------------------------
# property-based parity (hypothesis optional — gated via conftest)
# ---------------------------------------------------------------------------
given, settings, st = hypothesis_tools()

_PROP = {}  # (sync_every, kind) -> engine, reused across examples


def _prop_engine(setup, sync_every, kind):
    cfg, params = setup
    key = (sync_every, kind)
    if key not in _PROP:
        mesh = make_lane_mesh(8) if kind == "lane" else None
        _PROP[key] = _engine(cfg, params, mesh, sync_every=sync_every,
                             max_side=8, side_max_steps=4)
    eng = _PROP[key]
    for s in eng.sides:  # clear streams left over from the previous example
        if s.active:
            eng.retire_side(s.lane)
    return eng


@needs_mesh
@settings(max_examples=4, deadline=None)
@given(
    prompt=st.text(alphabet="abcdef ", min_size=1, max_size=12),
    with_task=st.booleans(),
    sync_every=st.sampled_from([2, 4]),
    n_windows=st.integers(min_value=1, max_value=2),
    extra=st.integers(min_value=0, max_value=1),
)
def test_property_lane_sharded_equals_single_device(setup, prompt, with_task,
                                                    sync_every, n_windows, extra):
    """Random prompts, window sizes, and spawn/merge interleavings: the
    lane-sharded engine equals the single-device engine token-for-token on
    greedy lanes (main AND side), including partial trailing windows."""
    text = prompt + (" [TASK: check] tail" if with_task else "")
    n = n_windows * sync_every + extra
    lane = _prop_engine(setup, sync_every, "lane")
    ref = _prop_engine(setup, sync_every, "ref")
    ml = lane.submit(text, lane=0)
    mr = ref.submit(text, lane=0)
    base = lane.stats["tick_dispatches"]
    lane.run(n)
    ref.run(n)
    assert ml.tokens == mr.tokens
    for sl, sr in zip(lane.sides, ref.sides):
        assert sl.tokens == sr.tokens
    assert lane.stats["tick_dispatches"] - base == math.ceil(n / sync_every)
