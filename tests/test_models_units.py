"""Unit tests for the model substrate: MoE dispatch, Mamba2 chunked SSD,
RWKV6 recurrence, rope, blocked attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_tools

given, settings, st = hypothesis_tools()  # stubs skip ONLY the property tests

from repro.configs import get_config
from repro.kernels.ref import mamba2_chunk_ref
from repro.models import attention, cache as cache_lib, mamba2, moe, rwkv6
from repro.models.config import ModelConfig
from repro.models.layers import apply_mrope, apply_rope


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def _moe_cfg(E=4, k=2, dm=32, ff=64):
    return dataclasses.replace(
        get_config("qwen3-moe-30b-a3b", reduced=True),
        n_experts=E,
        experts_per_token=k,
        d_model=dm,
        d_ff=ff,
        n_shared_experts=0,
        moe_capacity_factor=100.0,  # dropless for the equivalence test
        compute_dtype="float32",
    )


def _dense_moe_reference(p, cfg, x):
    """Per-token explicit expert evaluation (no dispatch tricks)."""
    T, dm = x.shape
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    g, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    g = g / g.sum(-1, keepdims=True)
    out = jnp.zeros_like(x)
    for t in range(T):
        acc = jnp.zeros((dm,))
        for j in range(cfg.experts_per_token):
            e = int(idx[t, j])
            h = jax.nn.silu(x[t] @ p["experts"]["gate"][e]) * (x[t] @ p["experts"]["up"][e])
            acc = acc + g[t, j] * (h @ p["experts"]["down"][e])
        out = out.at[t].set(acc)
    return out


def test_moe_dispatch_matches_dense_reference():
    cfg = _moe_cfg()
    p = moe.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 12, cfg.d_model))
    y, aux = moe.moe_forward(p, cfg, x)
    y_ref = _dense_moe_reference(p, cfg, x[0])
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    assert float(aux["drop_frac"]) == 0.0


def test_moe_capacity_drops_counted():
    cfg = dataclasses.replace(_moe_cfg(), moe_capacity_factor=0.25)
    p = moe.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
    _, aux = moe.moe_forward(p, cfg, x)
    assert float(aux["drop_frac"]) > 0.0


def test_moe_lb_loss_uniform_is_one():
    """With perfectly uniform routing the switch loss ~= E * (1/E * k/E * E/k)
    -> lower-bounded by 1 after the standard normalization."""
    cfg = _moe_cfg(E=8, k=2)
    p = moe.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(5), (4, 128, cfg.d_model))
    _, aux = moe.moe_forward(p, cfg, x)
    assert float(aux["lb_loss"]) >= cfg.experts_per_token * 0.98


def test_moe_shared_expert_added():
    cfg = dataclasses.replace(_moe_cfg(), n_shared_experts=1)
    p = moe.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    y_with, _ = moe.moe_forward(p, cfg, x)
    p2 = dict(p)
    p2_shared = jax.tree.map(jnp.zeros_like, p["shared"])
    p2 = {**p, "shared": p2_shared}
    y_zero_shared, _ = moe.moe_forward(p2, cfg, x)
    assert float(jnp.abs(y_with - y_zero_shared).max()) > 1e-5


# ---------------------------------------------------------------------------
# Mamba2: chunked SSD vs naive recurrence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (48, 16)])
def test_mamba2_chunked_matches_recurrence(S, chunk):
    cfg = dataclasses.replace(
        get_config("zamba2-1.2b", reduced=True),
        compute_dtype="float32",
        ssm_chunk=chunk,
        shared_attn_every=0,
    )
    p = mamba2.mamba2_init(jax.random.key(0), cfg, jnp.float32)
    B = 2
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.5
    y_chunked = mamba2.mamba2_forward(p, cfg, x)

    # naive recurrence through the decode step
    state = cache_lib.init_mamba2_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        yt, state = mamba2.mamba2_decode(p, cfg, x[:, t : t + 1], state)
        outs.append(yt)
    y_naive = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_naive), rtol=2e-3, atol=2e-3)


def test_mamba2_terminal_state_matches_decode_chain():
    cfg = dataclasses.replace(
        get_config("zamba2-1.2b", reduced=True),
        compute_dtype="float32",
        ssm_chunk=8,
        shared_attn_every=0,
    )
    p = mamba2.mamba2_init(jax.random.key(0), cfg, jnp.float32)
    B, S = 1, 24
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.5
    _, state_fwd = mamba2.mamba2_forward(p, cfg, x, return_state=True)
    state = cache_lib.init_mamba2_state(cfg, B, jnp.float32)
    for t in range(S):
        _, state = mamba2.mamba2_decode(p, cfg, x[:, t : t + 1], state)
    np.testing.assert_allclose(np.asarray(state_fwd.ssm), np.asarray(state.ssm), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(state_fwd.conv, np.float32), np.asarray(state.conv, np.float32), rtol=1e-4, atol=1e-5
    )


def test_mamba2_chunk_ref_oracle():
    """The kernel-test oracle itself agrees with an independent numpy loop."""
    B, S, nh, dh, ds = 1, 16, 2, 4, 3
    ks = jax.random.split(jax.random.key(2), 4)
    x = jax.random.normal(ks[0], (B, S, nh, dh))
    la = -jax.random.uniform(ks[1], (B, S, nh))
    b = jax.random.normal(ks[2], (B, S, ds))
    c = jax.random.normal(ks[3], (B, S, ds))
    y = mamba2_chunk_ref(x, la, b, c, chunk=4)
    state = np.zeros((nh, dh, ds))
    for t in range(S):
        state = state * np.exp(np.asarray(la)[0, t])[:, None, None] + np.einsum(
            "hd,s->hds", np.asarray(x)[0, t], np.asarray(b)[0, t]
        )
        yt = np.einsum("hds,s->hd", state, np.asarray(c)[0, t])
        np.testing.assert_allclose(np.asarray(y)[0, t], yt, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6: parallel scan vs decode chain
# ---------------------------------------------------------------------------
def test_rwkv6_forward_matches_decode_chain():
    cfg = dataclasses.replace(get_config("rwkv6-1.6b", reduced=True), compute_dtype="float32")
    tp = rwkv6.rwkv6_tmix_init(jax.random.key(0), cfg, jnp.float32)
    cp = rwkv6.rwkv6_cmix_init(jax.random.key(1), cfg, jnp.float32)
    B, S = 2, 20
    x = jax.random.normal(jax.random.key(2), (B, S, cfg.d_model)) * 0.5
    y_fwd, (shift, wkv) = rwkv6.rwkv6_tmix_forward(tp, cfg, x)
    state = cache_lib.init_rwkv6_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        yt, state = rwkv6.rwkv6_tmix_decode(tp, cfg, x[:, t : t + 1], state)
        outs.append(yt)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_fwd), np.asarray(y_dec), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(wkv), np.asarray(state.wkv), rtol=2e-4, atol=2e-4)

    yc_fwd, last = rwkv6.rwkv6_cmix_forward(cp, cfg, x)
    state2 = cache_lib.init_rwkv6_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        yt, state2 = rwkv6.rwkv6_cmix_decode(cp, cfg, x[:, t : t + 1], state2)
        outs.append(yt)
    np.testing.assert_allclose(
        np.asarray(yc_fwd), np.asarray(jnp.concatenate(outs, 1)), rtol=2e-4, atol=2e-4
    )


def test_rwkv6_decay_in_unit_interval():
    cfg = dataclasses.replace(get_config("rwkv6-1.6b", reduced=True), compute_dtype="float32")
    tp = rwkv6.rwkv6_tmix_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    _, _, _, _, w = rwkv6._tmix_projections(tp, cfg, x, jnp.zeros_like(x))
    assert float(w.min()) > 0.0 and float(w.max()) < 1.0


# ---------------------------------------------------------------------------
# rope / mrope / attention
# ---------------------------------------------------------------------------
def test_rope_relative_property():
    """q.k after rope depends only on relative distance."""
    d = 32
    q = jax.random.normal(jax.random.key(0), (1, 1, 1, d))
    k = jax.random.normal(jax.random.key(1), (1, 1, 1, d))
    def score(tq, tk):
        qr = apply_rope(q, jnp.asarray([[tq]]), 10000.0)
        kr = apply_rope(k, jnp.asarray([[tk]]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 4), rel=1e-3)


def test_mrope_reduces_to_rope_for_text():
    d = 32
    x = jax.random.normal(jax.random.key(0), (2, 6, 4, d))
    pos = jnp.broadcast_to(jnp.arange(6, dtype=jnp.int32)[None], (2, 6))
    pos3 = jnp.broadcast_to(pos[:, None, :], (2, 3, 6))
    sections = (4, 6, 6)
    a = apply_rope(x, pos, 10000.0)
    b = apply_mrope(x, pos3, 10000.0, sections)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(4, 48), chunk=st.sampled_from([4, 8, 16, 1024]))
def test_blocked_attention_matches_naive(S, chunk):
    B, H, Hkv, D = 1, 4, 2, 16
    ks = jax.random.split(jax.random.key(42), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = attention.blocked_attention(q, k, v, causal=True, chunk=chunk)
    # naive
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bkgqt,btkd->bqkgd", p, v).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
