"""Fast benchmark smoke: delegates to benchmarks.run.smoke() — the SAME
function the CI `benchmarks/run.py --smoke` step executes — so the
macro-tick dispatch-accounting assertions (amortized 1/sync_every
dispatches per virtual tick, sync_every ticks per dispatch) live in
exactly one place and cannot drift between the two entry points."""
from benchmarks import run as bench_run


def test_bench_throughput_reduced_iteration():
    out = bench_run.smoke()
    # shape serialized by benchmarks/run.py into BENCH_throughput.json
    assert set(out) == {"sync_every", "per_side", "ab", "adaptive"}
    assert out["per_side"][2]["tick_s_mean"] >= out["per_side"][2]["tick_s"]
    # serial vs pipelined A/B measures the same virtual ticks either way
    assert out["ab"]["serial_tick_s"] > 0 and out["ab"]["pipelined_tick_s"] > 0
    # the adaptive histogram's tick mass equals the ticks it advanced
    # (window accounting can't silently drop or double-count dispatches)
    hist = out["adaptive"]["window_hist"]
    assert sum(w * c for w, c in hist.items()) == out["adaptive"]["ticks"]
