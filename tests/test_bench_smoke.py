"""Fast benchmark smoke: delegates to benchmarks.run.smoke() — the SAME
function the CI `benchmarks/run.py --smoke` step executes — so the
macro-tick dispatch-accounting assertions (amortized 1/sync_every
dispatches per virtual tick, sync_every ticks per dispatch) live in
exactly one place and cannot drift between the two entry points."""
from benchmarks import run as bench_run


def test_bench_throughput_reduced_iteration():
    out = bench_run.smoke()
    # shape serialized by benchmarks/run.py into BENCH_throughput.json
    assert set(out) == {"sync_every", "per_side"}
    assert out["per_side"][2]["tick_s_mean"] >= out["per_side"][2]["tick_s"]
