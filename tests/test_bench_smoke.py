"""Fast benchmark smoke: one reduced bench_throughput iteration imports and
runs, reports the fused-tick invariants (1 dispatch/tick), and produces the
shape that benchmarks/run.py serializes into BENCH_throughput.json."""
from benchmarks import bench_throughput


def test_bench_throughput_reduced_iteration():
    out = bench_throughput.run(side_counts=(2,), ticks=2, warmup=4, sync_every=2)
    assert out["sync_every"] == 2
    res = out["per_side"][2]
    assert res["tick_s"] > 0
    assert res["active"] == 2
    # fused engine: exactly one jitted dispatch per tick
    assert res["dispatches_per_tick"] == 1.0
    # drains every sync_every ticks -> at most 1/sync_every syncs per tick
    assert res["host_syncs_per_tick"] <= 1.0 / out["sync_every"] + 1e-9
