"""Optimizer, schedules, loss, checkpoint, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_tools

given, settings, st = hypothesis_tools()  # stubs skip ONLY the property tests

from repro.checkpoint import io as ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticCorpus, make_batch
from repro.data.tokenizer import ByteTokenizer
from repro.serving.sampler import SamplingParams, sample
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw, lr_at
from repro.training.trainer import init_train_state, make_train_step


def test_loss_decreases_smollm():
    cfg = get_config("smollm-135m", reduced=True)
    state = init_train_state(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, DataConfig(seq_len=64, batch_size=8, seed=i)).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(grad_clip=1.0, lr=1.0, warmup_steps=0, total_steps=10, schedule="constant")
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 1e6)}
    state = init_adamw(params)
    _, _, metrics = adamw_update(cfg, params, grads, state)
    # clipped global norm reported as the raw norm
    assert float(metrics["grad_norm"]) > 1e5


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, grad_clip=0, warmup_steps=0,
                      total_steps=10, schedule="constant")
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    grads = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    new_p, _, _ = adamw_update(cfg, params, grads, init_adamw(params))
    assert float(new_p["w"].max()) < 1.0   # decayed
    assert float(new_p["b"].min()) == 1.0  # exempt


def test_checkpoint_roundtrip_nested():
    pytest.importorskip("zstandard")  # optional compression dep
    cfg = get_config("qwen3-4b", reduced=True)
    state = init_train_state(jax.random.key(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.msgpack.zst")
        ckpt.save(path, state)
        restored = ckpt.load(path, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corpus_deterministic_and_learnable_structure():
    c1 = SyntheticCorpus(DataConfig(seq_len=32, batch_size=4, seed=7))
    c2 = SyntheticCorpus(DataConfig(seq_len=32, batch_size=4, seed=7))
    b1, b2 = c1.batch(), c2.batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 256
    # copy docs contain the separator
    flat = b1["tokens"].flatten()
    assert (flat == ord("|")).sum() >= 0


def test_tokenizer_roundtrip():
    tok = ByteTokenizer(512)
    for text in ["hello", "[TASK: xyz]", "ünïcødé"]:
        assert tok.decode(tok.encode(text)) == text


@settings(max_examples=20, deadline=None)
@given(temp=st.floats(0.1, 2.0), k=st.integers(1, 10), seed=st.integers(0, 1000))
def test_sampler_topk_support(temp, k, seed):
    logits = jax.random.normal(jax.random.key(seed), (2, 32))
    t = sample(jax.random.key(seed + 1), logits, SamplingParams(temperature=temp, top_k=k))
    topk_sets = jax.lax.top_k(logits, k)[1]
    for b in range(2):
        assert int(t[b]) in np.asarray(topk_sets[b]).tolist()


def test_sampler_greedy():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    t = sample(jax.random.key(0), logits, SamplingParams(greedy=True))
    assert int(t[0]) == 1
