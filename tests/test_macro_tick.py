"""Macro-tick engine invariants (ISSUE 4 acceptance criteria).

The contract this suite pins down:

* PARITY — `run(n)` (scanned macro windows) produces bitwise-identical
  token streams, event history, and memory accounting to the PR 3
  single-tick path (`tick()` loop) on greedy lanes, across spawn/merge
  interleavings;
* DISPATCH COUNT — `run(n)` from a window boundary issues exactly
  ``ceil(n / sync_every)`` fused-tick dispatches (full windows ride one
  ``lax.scan`` dispatch, the trailing partial window one shorter scan);
* ZERO HOST SYNCS — nothing crosses the device boundary inside a macro
  window (enforced with ``jax.transfer_guard("disallow")``, not just the
  engine's self-reported counters);
* DONATION — the scanned dispatch donates the TickState like the single
  tick does: no cache-aliasing errors, and ``memory_report`` shows no
  peak-cache growth versus the single-tick engine;
* PER-LANE SAMPLING — a greedy lane is bitwise unaffected by the other
  lanes' temperature/top-k/top-p, and ``temperature=0`` reduces exactly
  to argmax (``greedy=True``).
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_tools
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams, sample_lanes, stack_lane_params


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


def _engine(cfg, params, *, sync_every=4, max_side=2, theta=2.0, side_max_steps=6,
            sampling=SamplingParams(greedy=True), side_sampling=None):
    prism = Prism(params, cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    return CortexEngine(
        prism, tok, n_main=1, max_side=max_side, main_capacity=128,
        side_max_steps=side_max_steps, inject_tokens=8, theta=theta,
        sampling=sampling, side_sampling=side_sampling, sync_every=sync_every,
    )


def _run_single_tick(eng, n):
    """The PR 3 reference path: one dispatch per virtual tick."""
    for _ in range(n):
        eng.tick()
    eng.drain()


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def pair(setup):
    """The same spawn/merge workload on the macro path and the single-tick
    path (theta=-1 accepts merges, so side thoughts mutate the main cache
    mid-run — parity must survive the full control plane)."""
    cfg, params = setup
    kw = dict(sync_every=4, max_side=2, theta=-1.0, side_max_steps=6)
    macro = _engine(cfg, params, **kw)
    single = _engine(cfg, params, **kw)
    prompt = "hello [TASK: go] world"
    macro.submit(prompt, lane=0)
    single.submit(prompt, lane=0)
    base = dict(macro.stats)
    macro.run(24)
    _run_single_tick(single, 24)
    return macro, single, base


def test_macro_matches_single_tick_bitwise(pair):
    macro, single, _ = pair
    assert macro.mains[0].tokens == single.mains[0].tokens
    for sm, ss in zip(macro.sides, single.sides):
        assert sm.tokens == ss.tokens
    # the control plane interleaved identically: same events, same verdicts
    assert [(e["event"], e.get("accepted")) for e in macro.history] == \
           [(e["event"], e.get("accepted")) for e in single.history]
    assert any(e["event"] == "merge" for e in macro.history)


def test_macro_dispatch_count_is_amortized(pair):
    macro, single, base = pair
    # 24 ticks @ sync_every=4: six scanned dispatches vs twenty-four
    assert macro.stats["tick_dispatches"] - base["tick_dispatches"] == 24 // 4
    assert macro.stats["macro_dispatches"] - base["macro_dispatches"] == 24 // 4
    assert macro.stats["ticks"] - base["ticks"] == 24
    # same drain cadence as the single-tick engine
    assert macro.stats["drains"] - base["drains"] == 24 // 4


def test_macro_donation_no_peak_memory_growth(pair):
    """Donated scan: the macro engine holds exactly the same resident cache
    bytes as the single-tick engine — a failed donation would have doubled
    the cache footprint (or raised a buffer-aliasing error mid-run)."""
    macro, single, _ = pair
    rep_m = macro.memory_report()
    rep_s = single.memory_report()
    assert rep_m["total_bytes"] == rep_s["total_bytes"]
    assert rep_m["n_agents"] == rep_s["n_agents"]
    # more macro windows leave the footprint bit-stable
    macro.run(8)
    assert macro.memory_report()["total_bytes"] == rep_m["total_bytes"]


def test_dispatch_count_is_ceil_for_partial_windows(setup):
    cfg, params = setup
    eng = _engine(cfg, params, sync_every=4, max_side=1)
    eng.submit("ceil probe", lane=0)
    for n in (8, 7, 3, 1):
        base = eng.stats["tick_dispatches"]
        eng.run(n)  # always starts/ends on a drain boundary
        assert eng.stats["tick_dispatches"] - base == math.ceil(n / 4), n


def test_zero_host_syncs_inside_macro_window(setup):
    """The whole sync_every window runs with device<->host transfers hard
    disallowed; only the drain (outside the guard) touches the host."""
    cfg, params = setup
    eng = _engine(cfg, params, sync_every=4, max_side=1)
    m = eng.submit("transfer guard probe", lane=0)
    eng.run(8)  # warm the scanned dispatch + drain
    base = dict(eng.stats)
    n_tok = len(m.tokens)
    with jax.transfer_guard("disallow"):
        eng._dispatch_window(eng.sync_every)
    assert eng.stats["tick_dispatches"] - base["tick_dispatches"] == 1
    assert eng.stats["macro_dispatches"] - base["macro_dispatches"] == 1
    assert eng.stats["host_syncs"] == base["host_syncs"]
    assert eng.stats["drains"] == base["drains"]
    eng.drain()  # ONE pull of the rings closes the window
    assert eng.stats["host_syncs"] == base["host_syncs"] + 1
    assert len(m.tokens) == n_tok + eng.sync_every


def test_greedy_lane_unaffected_by_other_lanes_params(setup):
    """Per-lane sampling determinism: the greedy river's stream is bitwise
    invariant under the side lanes' exploration params (same PRNG seed)."""
    cfg, params = setup
    streams = []
    for side_sampling in (
        SamplingParams(temperature=0.9, top_k=8),
        SamplingParams(temperature=1.4, top_p=0.8),
    ):
        eng = _engine(cfg, params, sync_every=4, max_side=1,
                      side_sampling=side_sampling, side_max_steps=64)
        m = eng.submit("probe [TASK: explore] x", lane=0)
        eng.run(12)
        assert any(s.active for s in eng.sides)  # the stochastic lane ran
        streams.append(list(m.tokens))
    assert streams[0] == streams[1]


def test_temperature_zero_reduces_to_argmax(pair, setup):
    """An engine submitted with temperature=0 equals the greedy=True engine
    token-for-token on the same workload."""
    cfg, params = setup
    _, single, _ = pair
    eng = _engine(cfg, params, sync_every=4, max_side=2, theta=-1.0, side_max_steps=6,
                  sampling=SamplingParams(temperature=0.0))
    eng.submit("hello [TASK: go] world", lane=0)
    eng.run(24)
    assert eng.mains[0].tokens == single.mains[0].tokens


def test_sample_lanes_units():
    """Direct sampler contract: greedy/top-k=1 lanes are argmax; lane
    params are independent (changing lane 1 cannot move lane 0)."""
    logits = jax.random.normal(jax.random.key(1), (3, 97))
    am = jnp.argmax(logits, axis=-1)
    key = jax.random.key(2)
    t = sample_lanes(key, logits, stack_lane_params([
        SamplingParams(temperature=0.0),
        SamplingParams(temperature=1.0, top_k=1),
        SamplingParams(temperature=1.2, top_p=0.85),
    ]))
    assert int(t[0]) == int(am[0])       # temperature=0 -> argmax
    assert int(t[1]) == int(am[1])       # top_k=1 -> argmax at any temp
    # greedy=True flag and temperature=0 are the same lane encoding
    t2 = sample_lanes(key, logits, stack_lane_params([
        SamplingParams(greedy=True),
        SamplingParams(temperature=0.7),
        SamplingParams(temperature=0.3, top_k=5),
    ]))
    assert int(t2[0]) == int(am[0])
    # top_p so tight only the top token survives -> argmax
    t3 = sample_lanes(key, logits, stack_lane_params([
        SamplingParams(temperature=1.0, top_p=1e-6),
        SamplingParams(temperature=1.0, top_p=1e-6),
        SamplingParams(temperature=1.0, top_p=1e-6),
    ]))
    np.testing.assert_array_equal(np.asarray(t3), np.asarray(am))


def test_top_p_nests_inside_top_k():
    """Combined filters match sample(): the nucleus is taken from the
    RENORMALIZED post-top-k distribution. probs [0.4, 0.3, 0.3] with
    top_k=2 renormalizes to [0.571, 0.429]; top_p=0.5 then keeps only the
    top token — so every draw must be argmax."""
    logits = jnp.log(jnp.asarray([[0.4, 0.3, 0.3]] * 2))
    lanes = stack_lane_params([SamplingParams(temperature=1.0, top_k=2, top_p=0.5)] * 2)
    for seed in range(8):
        t = sample_lanes(jax.random.key(seed), logits, lanes)
        np.testing.assert_array_equal(np.asarray(t), np.zeros(2, np.int32))


# ---------------------------------------------------------------------------
# property-based parity (hypothesis optional — gated via conftest)
# ---------------------------------------------------------------------------
given, settings, st = hypothesis_tools()

_PROP = {}  # (sync_every, kind) -> engine, reused across examples


def _prop_engine(setup, sync_every, kind):
    cfg, params = setup
    key = (sync_every, kind)
    if key not in _PROP:
        _PROP[key] = _engine(cfg, params, sync_every=sync_every, max_side=2,
                             theta=-1.0, side_max_steps=4)
    eng = _PROP[key]
    for s in eng.sides:  # clear streams left over from the previous example
        if s.active:
            eng.retire_side(s.lane)
    return eng


@settings(max_examples=5, deadline=None)
@given(
    prompt=st.text(alphabet="abcdef ", min_size=1, max_size=12),
    with_task=st.booleans(),
    sync_every=st.sampled_from([1, 2, 4, 8]),
    n_windows=st.integers(min_value=1, max_value=2),
    extra=st.integers(min_value=0, max_value=1),
)
def test_property_macro_equals_single_tick(setup, prompt, with_task, sync_every, n_windows, extra):
    """Random prompts, window sizes, and spawn/merge interleavings: the
    macro-tick engine equals the single-tick engine token-for-token on
    greedy lanes (main AND side), including partial trailing windows."""
    text = prompt + (" [TASK: check] tail" if with_task else "")
    n = n_windows * sync_every + extra
    macro = _prop_engine(setup, sync_every, "macro")
    single = _prop_engine(setup, sync_every, "single")
    mm = macro.submit(text, lane=0)
    ms = single.submit(text, lane=0)
    base = macro.stats["tick_dispatches"]
    macro.run(n)
    _run_single_tick(single, n)
    assert mm.tokens == ms.tokens
    for sm, ss in zip(macro.sides, single.sides):
        assert sm.tokens == ss.tokens
    assert macro.stats["tick_dispatches"] - base == math.ceil(n / sync_every)
