"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # B, H, Hkv, D, T
    (1, 4, 4, 64, 128),
    (2, 8, 2, 64, 200),
    (2, 9, 3, 64, 321),
    (3, 16, 2, 80, 1000),
    (1, 32, 8, 128, 4096),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_synapse_attention_matches_ref(shape, dtype):
    B, H, Hkv, D, T = shape
    ks = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    keys = jax.random.normal(ks[1], (B, T, Hkv, D)).astype(dtype)
    vals = jax.random.normal(ks[2], (B, T, Hkv, D)).astype(dtype)
    valid = jax.random.bernoulli(ks[3], 0.7, (B, T)).at[:, 0].set(True)
    out, mass = ops.synapse_attention(q, keys, vals, valid)
    out_r, mass_r = ref.synapse_attention_ref(q, keys, vals, valid)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(out_r, np.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(np.asarray(mass), np.asarray(mass_r), **_tol(dtype))
    # probability mass conserves: sums to H per lane
    np.testing.assert_allclose(np.asarray(mass.sum(-1)), H, rtol=1e-3)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_landmark_score_matches_ref(shape, dtype):
    B, H, Hkv, D, T = shape
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    keys = jax.random.normal(ks[1], (B, T, Hkv, D)).astype(dtype)
    lm = jax.random.normal(ks[2], (B, 7, D)).astype(dtype)
    dens, dist = ops.landmark_score(q, keys, lm, block_t=128)
    logits_r, dist_r = ref.landmark_score_ref(q, keys, lm)
    dens_r = jax.nn.softmax(logits_r, -1).sum(1)
    np.testing.assert_allclose(np.asarray(dens), np.asarray(dens_r), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_r), **_tol(dtype))


def test_masked_keys_get_zero_mass():
    B, H, Hkv, D, T = 1, 4, 2, 64, 256
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    keys = jax.random.normal(ks[1], (B, T, Hkv, D))
    vals = jax.random.normal(ks[2], (B, T, Hkv, D))
    valid = jnp.zeros((B, T), bool).at[:, :10].set(True)
    _, mass = ops.synapse_attention(q, keys, vals, valid)
    assert float(mass[:, 10:].max()) < 1e-9
    np.testing.assert_allclose(float(mass.sum()), H, rtol=1e-4)


def test_kernel_used_in_synapse_decode_path_is_equivalent():
    """The pure-jnp decode_attend and the kernel agree — the engine may swap
    either in (ops.py is the serving hot path on TPU)."""
    from repro.models.attention import decode_attend

    B, H, Hkv, D, T = 2, 8, 4, 64, 96
    ks = jax.random.split(jax.random.key(3), 4)
    q = jax.random.normal(ks[0], (B, H, D))
    keys = jax.random.normal(ks[1], (B, T, Hkv, D))
    vals = jax.random.normal(ks[2], (B, T, Hkv, D))
    valid = jnp.ones((B, T), bool)
    out_k, mass_k = ops.synapse_attention(q, keys, vals, valid)
    out_j, mass_j = decode_attend(q, keys, vals, valid)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mass_k), np.asarray(mass_j), rtol=1e-5, atol=1e-5)
