"""Adaptive macro windows + pipelined drains (ISSUE 5 acceptance criteria).

The contract this suite pins down:

* CHURN PARITY — sequences of submit/spawn/merge/retire interleaved with
  ``run(n)`` produce bitwise-identical greedy token streams (main AND side)
  and identical control-plane histories on the pipelined-pinned and the
  pipelined-adaptive engines vs the serial PR 4 reference
  (``pipeline=False``), including partial windows and lane restarts;
* DISPATCH ACCOUNTING — ``run(n)`` from a boundary issues at most
  ``ceil(n / sync_every)`` dispatches, exactly that many when adaptation is
  off, and the window histogram's tick mass equals the ticks advanced;
* OVERLAP — the pipelined drain's post-processing region (router scan,
  UTF-8 decode, bookkeeping) issues ZERO device transfers while the next
  window executes — enforced with ``jax.transfer_guard("disallow")``, not
  just the engine's self-reported counters;
* ADAPTATION — trigger-free drains climb the window ladder to
  ``max_window``; any admission/trigger/merge snaps back to the base
  window; scan-length jit variants stay bounded by the fixed ladder;
* SERVER — BatchServer's pipelined decode matches its serial loop bitwise,
  and a recycled lane never inherits the previous request's sampling params
  (the samp cache invalidates on every composition change).
"""
import dataclasses
import math

import jax
import pytest

from conftest import hypothesis_tools
from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer


def _cfg():
    return dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def _engine(cfg, params, *, pipeline, max_window=None, sync_every=4,
            side_max_steps=6, sampling=SamplingParams(greedy=True),
            side_sampling=None):
    prism = Prism(params, cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    return CortexEngine(
        prism, tok, n_main=1, max_side=2, main_capacity=128,
        side_max_steps=side_max_steps, inject_tokens=8, theta=-1.0,
        sampling=sampling, side_sampling=side_sampling,
        sync_every=sync_every, max_window=max_window, pipeline=pipeline,
    )


def _apply(eng, ops):
    """One churn script, engine-agnostic: the same op sequence must drive
    every engine variant through identical control-plane decisions."""
    deltas = []  # (op, n, tick_dispatches delta) for run ops
    for op in ops:
        if op[0] == "submit":
            eng.submit(op[1], lane=0)
        elif op[0] == "run":
            d0 = eng.stats["tick_dispatches"]
            eng.run(op[1])
            deltas.append((op[1], eng.stats["tick_dispatches"] - d0))
        elif op[0] == "spawn":
            # drain-boundary spawn, bypassing the router (direct churn)
            eng._spawn_side(eng.mains[0], op[1])
        elif op[0] == "retire":
            eng.retire_side(op[1])
    return deltas


def _streams(eng):
    return (
        list(eng.mains[0].tokens),
        [list(s.tokens) for s in eng.sides],
        [(e["event"], e.get("accepted")) for e in eng.history],
    )


CHURN_SCRIPT = [
    ("submit", "hello [TASK: go] world"),
    ("run", 7),               # partial trailing window
    ("spawn", "second probe"),
    ("run", 9),               # budget completions -> merges mid-script
    ("retire", 0),
    ("retire", 1),
    ("run", 5),
    ("submit", "calm text with no tags at all"),  # lane restart
    ("run", 24),              # trigger-free stretch: windows may lengthen
    ("run", 3),
]


@pytest.fixture(scope="module")
def churn(setup):
    cfg, params = setup
    engines = {
        "serial": _engine(cfg, params, pipeline=False),
        "pinned": _engine(cfg, params, pipeline=True),
        "adaptive": _engine(cfg, params, pipeline=True, max_window=16),
    }
    deltas = {k: _apply(e, CHURN_SCRIPT) for k, e in engines.items()}
    return engines, deltas


def test_churn_parity_bitwise(churn):
    """Pipelined (pinned AND adaptive) == serial PR 4 path, token-for-token
    and event-for-event, across spawn/merge/retire churn."""
    engines, _ = churn
    ref = _streams(engines["serial"])
    assert _streams(engines["pinned"]) == ref
    assert _streams(engines["adaptive"]) == ref
    # the script actually exercised the control plane
    events = [e for e, _ in ref[2]]
    assert "spawn" in events and "merge" in events and "retire" in events


def test_churn_dispatch_accounting(churn):
    """Per run(n) from a boundary: pinned issues exactly ceil(n/base)
    dispatches, adaptive at most that many (and fewer over the whole
    script, or it never adapted)."""
    engines, deltas = churn
    for n, d in deltas["pinned"]:
        assert d == math.ceil(n / 4), (n, d)
    for n, d in deltas["adaptive"]:
        assert d <= math.ceil(n / 4), (n, d)
    total_pinned = sum(d for _, d in deltas["pinned"])
    total_adaptive = sum(d for _, d in deltas["adaptive"])
    assert total_adaptive < total_pinned
    # serial and pinned agree exactly (pipelining reorders host work only)
    assert deltas["serial"] == deltas["pinned"]


def test_churn_window_hist_accounts_every_tick(churn):
    engines, _ = churn
    for eng in engines.values():
        hist = eng.stats["window_hist"]
        assert sum(w * c for w, c in hist.items()) == eng.stats["ticks"]
    assert max(engines["adaptive"].stats["window_hist"]) > 4   # lengthened
    assert max(engines["pinned"].stats["window_hist"]) == 4    # pinned
    assert engines["serial"].stats["overlapped_drains"] == 0
    assert engines["pinned"].stats["overlapped_drains"] > 0
    assert engines["adaptive"].stats["overlapped_drains"] > 0


def test_adaptive_ladder_is_bounded_and_snaps_back(setup):
    cfg, params = setup
    eng = _engine(cfg, params, pipeline=True, max_window=16)
    assert eng.window.ladder == (4, 8, 16)
    eng.submit("calm words only", lane=0)
    assert eng.window.propose() == 4  # admission resets
    eng.run(48)
    hist = eng.stats["window_hist"]
    assert hist.get(16, 0) >= 1, hist  # climbed to max_window
    assert eng.stats["tick_dispatches"] < math.ceil(48 / 4)
    # any admission snaps the proposal back to the base window
    eng.submit("another calm prompt", lane=0)
    assert eng.window.propose() == 4
    # the jit cache stays bounded by ladder rungs x variants (+ partials)
    lengths = {k[0] for k in eng._jit_macro}
    assert lengths <= {1, 3, 4, 8, 16}, lengths


def test_overlapped_budget_cap_sees_pending_window(setup):
    """Regression: in the overlapped branch the window policy runs BEFORE
    window t's post-processing, so the side step-budget cap must count
    window t's still-unprocessed ring tokens — with stale counters the
    boundary lands one window late, the merge drifts off the serial tick,
    and the main stream diverges (observed at sync_every=2, max_window=16,
    side_max_steps=9 before the fix)."""
    cfg, params = setup
    kw = dict(sync_every=2, side_max_steps=9)
    serial = _engine(cfg, params, pipeline=False, **kw)
    adaptive = _engine(cfg, params, pipeline=True, max_window=16, **kw)
    for eng in (serial, adaptive):
        eng.submit("hello [TASK: go] world", lane=0)
        eng.run(48)
    assert _streams(adaptive) == _streams(serial)
    assert any(e == "merge" for e, _ in _streams(serial)[2])
    assert max(adaptive.stats["window_hist"]) > 2  # windows did lengthen


def test_max_window_rounds_down_to_a_ladder_rung(setup):
    """A max_window that is not base*2^k would put drain boundaries off the
    base-multiple grid every serial invariant assumes — the ladder rounds
    it down instead (and the rings are sized to the effective rung)."""
    from repro.core.engine import AdaptiveWindow

    assert AdaptiveWindow(8, 12).ladder == (8,)
    assert AdaptiveWindow(8, 12).max_window == 8
    assert AdaptiveWindow(4, 17).ladder == (4, 8, 16)
    assert AdaptiveWindow(2, 16).ladder == (2, 4, 8, 16)
    cfg, params = setup
    eng = _engine(cfg, params, pipeline=True, sync_every=4, max_window=13)
    assert eng.max_window == 8
    assert eng.state.main_ring.shape[1] == 8  # ring capacity matches


def test_overlap_region_issues_no_transfers(setup):
    """The heart of the pipeline: with window t's rings fetched and the
    gate green, dispatching window t+1 AND doing window t's full host
    post-processing must not touch the device<->host boundary (the fetch
    itself, outside the guard, is the one blocking sync per window)."""
    cfg, params = setup
    eng = _engine(cfg, params, pipeline=True)
    m = eng.submit("transfer guard probe, no tags", lane=0)
    eng.run(8)  # warm the scanned dispatch + drain paths
    base = dict(eng.stats)
    n_tok = len(m.tokens)
    eng._dispatch_window(4)                  # window t
    rings = eng._fetch_rings()               # pipeline sync point
    with jax.transfer_guard("disallow"):
        assert eng._gate(rings, 4)
        eng._dispatch_window(4)              # window t+1 on the device
        eng._postprocess(rings, 4, overlapped=True)  # overlapped host work
    assert len(m.tokens) == n_tok + 4        # window t fully accounted
    assert eng.stats["host_syncs"] == base["host_syncs"] + 1
    eng.drain()                              # pipeline tail
    assert len(m.tokens) == n_tok + 8
    assert eng.stats["host_syncs"] == base["host_syncs"] + 2


def test_gate_is_conservative_on_trigger_bytes(setup):
    """Windows whose raw tokens could complete a tag, or whose sides reach
    their budget, must NOT overlap: the gate inspects ring bytes + the
    router's plausibility hint before the next dispatch is allowed."""
    cfg, params = setup
    eng = _engine(cfg, params, pipeline=True)
    eng.submit("x [TASK: go] y", lane=0)
    n0 = eng.stats["host_syncs"]
    eng._dispatch_window(4)
    rings = eng._fetch_rings()
    assert eng.stats["host_syncs"] == n0 + 1
    # forge a '[' into the main lane's window: gate must refuse to overlap
    forged = (rings[0].copy(), rings[1].copy())
    forged[0][0, 1] = ord("[")
    assert not eng._gate(forged, 4)
    # a ']' alone is only unsafe while the router tail holds an open '['
    forged2 = (rings[0].copy(), rings[1].copy())
    forged2[0][0, 1] = ord("]")
    rid = eng.mains[0].agent_id
    eng.router._tails[rid] = ("... [TA", 0)
    assert eng.router.plausible(rid)
    assert not eng._gate(forged2, 4)
    eng.router._tails[rid] = ("... [TASK: x] b", 0)
    assert not eng.router.plausible(rid)  # closed tail: ']' alone is safe
    # a side one token from its budget forces the serial path
    side = next(s for s in eng.sides if s.active)
    real_tokens = side.tokens
    try:
        side.tokens = real_tokens + [0] * (
            eng.side_max_steps + side.prompt_len - len(real_tokens)
        )
        assert not eng._gate(rings, 4)
    finally:
        side.tokens = real_tokens
    eng.drain()


def test_mixed_sampling_lanes_inside_adaptive_windows(setup):
    """Greedy river + filtered stochastic streams sharing one lengthened
    scan window: every lane's draws — greedy AND filtered — are bitwise
    identical to the serial fixed-window reference (the shared sampling
    pass is stable across window groupings because the PRNG splits per
    virtual tick and the static sampler flags only change at drains)."""
    cfg, params = setup
    kw = dict(side_max_steps=12,
              side_sampling=SamplingParams(temperature=1.1, top_k=12))
    serial = _engine(cfg, params, pipeline=False, **kw)
    adaptive = _engine(cfg, params, pipeline=True, max_window=16, **kw)
    for eng in (serial, adaptive):
        eng.submit("mixed [TASK: explore] lanes", lane=0)
        eng.run(28)
    assert _streams(adaptive) == _streams(serial)
    side = next(s for s in adaptive.sides if s.tokens)
    assert len(side.tokens) > side.prompt_len       # stochastic lane ran
    assert max(adaptive.stats["window_hist"]) > 4   # windows actually grew
    assert any(e == "merge" for e, _ in _streams(adaptive)[2])


def test_batchserver_pipeline_matches_serial(setup):
    """BatchServer's speculative pipelined decode == serial tick() loop,
    bitwise, across lane recycling (more requests than lanes)."""
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    reqs = [
        ("first request", 6, SamplingParams(greedy=True)),
        ("second request", 9, SamplingParams(temperature=0.9, top_k=8)),
        ("third request", 5, None),
        ("fourth request", 7, SamplingParams(temperature=1.2, top_p=0.9)),
    ]
    outs = []
    for pipeline in (True, False):
        srv = BatchServer(params, cfg, tok, n_lanes=2, capacity=64,
                          sampling=SamplingParams(temperature=1.0), seed=7)
        for prompt, mnt, sp in reqs:
            srv.submit(prompt, max_new_tokens=mnt, sampling=sp)
        done = srv.run_until_done(max_ticks=200, pipeline=pipeline)
        outs.append(sorted((r.rid, tuple(r.tokens)) for r in done))
        if pipeline:
            assert srv.stats["overlapped"] > 0
    assert outs[0] == outs[1]


def test_recycled_lane_never_inherits_sampling(setup):
    """Regression (ISSUE 5): after a greedy request completes, the lane's
    stacked sampling row must be rebuilt for the next occupant — admission,
    completion, and mid-flight cancel all invalidate the samp cache."""
    cfg, params = setup
    tok = ByteTokenizer(cfg.vocab_size)
    srv = BatchServer(params, cfg, tok, n_lanes=1, capacity=64,
                      sampling=SamplingParams(temperature=1.0))
    srv.submit("greedy req", max_new_tokens=3, sampling=SamplingParams(greedy=True))
    srv.run_until_done(max_ticks=50)
    assert not srv._samp_cache.valid          # completion invalidated
    srv.submit("default req", max_new_tokens=3)
    srv._admit()
    lanes_samp, use_filters, any_greedy = srv._samp_cache.get(srv._lane_params)
    assert float(lanes_samp.temperature[0]) == 1.0  # NOT the greedy 0.0
    assert not any_greedy and not use_filters
    # mid-flight retirement under the pipelined drain invalidates too
    rid = srv.lanes[0].rid
    assert srv.cancel(rid)
    assert not srv._samp_cache.valid
    assert srv.cancel(rid) is False            # already gone


# ---------------------------------------------------------------------------
# property-based churn stress (hypothesis optional — gated via conftest)
# ---------------------------------------------------------------------------
given, settings, st = hypothesis_tools()

_PROP = {}  # kind -> engine, reused across examples (jit caches are hot)


def _prop_engine(setup, kind):
    cfg, params = setup
    if kind not in _PROP:
        pipeline = kind != "serial"
        max_window = 16 if kind == "adaptive" else None
        _PROP[kind] = _engine(cfg, params, pipeline=pipeline,
                              max_window=max_window, side_max_steps=4)
    eng = _PROP[kind]
    for s in eng.sides:  # clear streams left over from the previous example
        if s.active:
            eng.retire_side(s.lane)
    return eng


_OP = st.one_of(
    st.tuples(st.just("run"), st.integers(min_value=1, max_value=11)),
    st.tuples(st.just("spawn"), st.sampled_from(["alpha", "beta"])),
    st.tuples(st.just("retire"), st.integers(min_value=0, max_value=1)),
    st.tuples(st.just("submit"), st.sampled_from(
        ["plain words", "tagged [TASK: t] words"])),
)


@settings(max_examples=5, deadline=None)
@given(
    prompt=st.text(alphabet="abcdef ", min_size=1, max_size=10),
    with_task=st.booleans(),
    ops=st.lists(_OP, min_size=2, max_size=6),
)
def test_property_churn_parity(setup, prompt, with_task, ops):
    """Randomized lane churn: submit/spawn/merge/retire interleaved with
    run(n) — pipelined pinned AND adaptive engines must equal the serial
    reference token-for-token (main and side lanes) with at most the serial
    dispatch count."""
    script = [("submit", prompt + (" [TASK: check] tail" if with_task else ""))]
    script += list(ops)
    results, deltas = {}, {}
    for kind in ("serial", "pinned", "adaptive"):
        eng = _prop_engine(setup, kind)
        h0 = len(eng.history)
        deltas[kind] = _apply(eng, script)
        m, sides, hist = _streams(eng)
        results[kind] = (m, sides, hist[h0:])
    assert results["pinned"] == results["serial"]
    assert results["adaptive"] == results["serial"]
    for (n, d_pin), (_, d_ser) in zip(deltas["pinned"], deltas["serial"]):
        assert d_pin == d_ser == math.ceil(n / 4)
    for n, d in deltas["adaptive"]:
        assert d <= math.ceil(n / 4)
