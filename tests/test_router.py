"""CortexRouter contract (ISSUE 5): incremental feeds, boundary splits,
duplicate suppression, the tail-size contract, and the trigger-plausibility
hint the pipelined engine's drain gate builds on."""
import dataclasses

import jax

from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism
from repro.core.router import CortexRouter
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib


TEXT = "pre amble [TASK: alpha beta] mid [DONE] post [ANSWER: gamma] end"


def _kinds(triggers):
    return [(t.kind, t.payload) for t in triggers]


def test_tags_split_across_drains_at_every_offset():
    """Whatever drain boundary cuts the stream — including inside a tag —
    each trigger fires exactly once, with absolute spans."""
    whole = CortexRouter().feed("ref", TEXT)
    expected = _kinds(whole)
    assert expected == [
        ("task", "alpha beta"), ("done", ""), ("answer", "gamma")
    ]
    spans = [t.span for t in whole]
    assert spans[0] == (TEXT.index("["), TEXT.index("]") + 1)
    for cut in range(len(TEXT) + 1):
        r = CortexRouter(tail=64)
        got = r.feed("a", TEXT[:cut]) + r.feed("a", TEXT[cut:])
        assert _kinds(got) == expected, cut
        assert [t.span for t in got] == spans, cut


def test_three_way_split_and_empty_chunks():
    for c1 in (5, 12, 20):
        for c2 in (c1, c1 + 7, 40):
            r = CortexRouter(tail=64)
            got = (r.feed("a", TEXT[:c1]) + r.feed("a", "")
                   + r.feed("a", TEXT[c1:c2]) + r.feed("a", TEXT[c2:]))
            assert _kinds(got) == _kinds(CortexRouter().feed("ref2", TEXT))


def test_feed_scan_mixing_suppresses_duplicates():
    """scan() (full text) and feed() (chunks) may interleave — a trigger
    already reported by either API must never fire again."""
    r = CortexRouter(tail=64)
    first = r.feed("a", TEXT[:30])          # contains the whole [TASK:] tag
    assert _kinds(first) == [("task", "alpha beta")]
    assert _kinds(r.scan("a", TEXT)) == [("done", ""), ("answer", "gamma")]
    assert r.scan("a", TEXT) == []          # fully scanned: idempotent
    assert r.feed("a", " [DONE]")[0].kind == "done"  # new text still fires


def test_tag_longer_than_tail_is_missed_documented():
    """The documented tail contract: once a tag outgrows the retained
    overlap, its opening '[' is evicted and a boundary-straddling match is
    (silently) dropped. This is WHY the engine must size its router tail
    >= the longest tag it round-trips."""
    tag = f"[TASK: {'x' * 40}]"
    r = CortexRouter(tail=8)                # tail << len(tag)
    cut = len(tag) // 2
    got = r.feed("a", tag[:cut]) + r.feed("a", tag[cut:])
    assert got == []                        # the miss, pinned on purpose
    # the same split with an adequate tail matches
    r2 = CortexRouter(tail=len(tag))
    got2 = r2.feed("a", tag[:cut]) + r2.feed("a", tag[cut:])
    assert _kinds(got2) == [("task", "x" * 40)]


def test_engine_sizes_tail_for_its_longest_tag_and_window():
    """Engine-side of the contract: the router tail covers the longest tag
    the engine round-trips ('[TASK: ' + side_prompt_cap bytes + ']') and a
    full max_window drain of text."""
    cfg = dataclasses.replace(
        get_config("qwen2.5-0.5b", reduced=True), compute_dtype="float32"
    )
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    for sync_every, max_window, cap in ((1, None, 64), (8, 64, 64), (4, 16, 200)):
        eng = CortexEngine(
            Prism(params, cfg), tok, n_main=1, max_side=1,
            sync_every=sync_every, max_window=max_window,
            side_prompt_cap=cap,
        )
        longest_tag = len("[TASK: ]") + cap
        assert eng.router._tail >= longest_tag
        assert eng.router._tail >= 8 * eng.max_window
        assert eng.router._tail >= 256


def test_plausible_hint():
    """plausible() == unclosed '[' in the retained tail: the adaptive
    window policy shortens on it and the pipelined gate refuses to overlap
    a ']'-bearing window while it holds."""
    r = CortexRouter(tail=64)
    assert not r.plausible("a")             # unknown agent: nothing pending
    r.feed("a", "calm text, no brackets")
    assert not r.plausible("a")
    r.feed("a", " now an open [TA")
    assert r.plausible("a")
    got = r.feed("a", "SK: finish] done")   # the split tag completes
    assert _kinds(got) == [("task", "finish")]
    assert not r.plausible("a")             # ']' closed it
    r.feed("a", " stray ] then [ again")
    assert r.plausible("a")
    r.reset("a")
    assert not r.plausible("a")


def test_spans_stay_absolute_across_many_feeds():
    r = CortexRouter(tail=16)
    r.feed("a", "x" * 100)
    got = r.feed("a", "[DONE]")
    assert got[0].span == (100, 106)
    got2 = r.feed("a", "y" * 3 + "[DONE]")
    assert got2[0].span == (109, 115)
