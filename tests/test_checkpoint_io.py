"""checkpoint/io codec contract (ISSUE 7 satellite).

The cold tier of the tiered synapse memory stores one `dumps()` blob per
hibernated agent and keeps only a ShapeDtypeStruct skeleton in RAM, so the
codec must round-trip BITWISE (a woken agent replays its greedy stream
exactly) across dtypes, restore into abstract skeletons, and fail loudly —
KeyError — when a blob is missing a leaf the skeleton expects.

The raw msgpack layer (`_encode_tree`/`_decode_tree`) has no optional deps
and is exercised unconditionally; the public zstd entry points gate on the
`zstandard` install exactly like the production code does.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io

needs_zstd = pytest.mark.skipif(
    ckpt_io.zstandard is None, reason="zstandard not installed"
)


def _mixed_tree(seed: int = 0):
    """Nested dict/list/tuple pytree over every dtype family the engine
    snapshots: f32 caches, int32 tokens/steps, bool masks, int64 scalars."""
    rng = np.random.default_rng(seed)
    return {
        "caches": [
            {"k": rng.standard_normal((3, 1, 4, 2)).astype(np.float32),
             "v": rng.standard_normal((3, 1, 4, 2)).astype(np.float32)},
            {"k": rng.standard_normal((2, 5)).astype(np.float16),
             "v": rng.standard_normal((2, 5)).astype(np.float64)},
        ],
        "tok": np.int32(17),
        "pos": np.int64(123456789),
        "mask": rng.random(7) > 0.5,
        "pair": (np.arange(6, dtype=np.uint8).reshape(2, 3),
                 np.asarray([-1, 0, 1], np.int16)),
    }


def _assert_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype
        assert x.shape == y.shape
        assert x.tobytes() == y.tobytes()  # bitwise, incl. NaN payloads


def _skeleton(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), tree
    )


def test_raw_codec_roundtrip_bitwise():
    tree = _mixed_tree()
    raw = ckpt_io._encode_tree(tree)
    back = ckpt_io._decode_tree(raw, tree, numpy=True)
    _assert_bitwise(tree, back)
    for leaf in jax.tree.leaves(back):
        assert isinstance(leaf, np.ndarray)


def test_raw_codec_restores_into_skeleton():
    """`like` may be all ShapeDtypeStructs — the cold tier keeps only the
    skeleton in RAM, never the arrays."""
    tree = _mixed_tree(1)
    raw = ckpt_io._encode_tree(tree)
    back = ckpt_io._decode_tree(raw, _skeleton(tree), numpy=True)
    _assert_bitwise(tree, back)


def test_raw_codec_device_leaves():
    """numpy=False lands jnp arrays; encoding accepts device arrays too."""
    tree = jax.tree.map(lambda a: jax.numpy.asarray(a), _mixed_tree(2))
    raw = ckpt_io._encode_tree(tree)
    back = ckpt_io._decode_tree(raw, _skeleton(tree))
    _assert_bitwise(tree, back)
    assert all(isinstance(x, jax.Array) for x in jax.tree.leaves(back))


def test_missing_leaf_raises_keyerror():
    tree = _mixed_tree(3)
    raw = ckpt_io._encode_tree({"tok": tree["tok"]})
    with pytest.raises(KeyError, match="checkpoint missing leaf"):
        ckpt_io._decode_tree(raw, tree, numpy=True)


def test_extra_leaves_are_ignored():
    """A blob may carry more than the skeleton asks for (forward compat);
    decode selects by path."""
    tree = _mixed_tree(4)
    raw = ckpt_io._encode_tree(tree)
    back = ckpt_io._decode_tree(raw, {"tok": tree["tok"]}, numpy=True)
    assert back["tok"] == tree["tok"]


def test_dumps_requires_zstd_when_missing():
    if ckpt_io.zstandard is not None:
        pytest.skip("zstandard installed: the gate cannot fire")
    with pytest.raises(ModuleNotFoundError, match="zstandard"):
        ckpt_io.dumps({"x": np.zeros(2)})


@needs_zstd
def test_dumps_loads_roundtrip_bitwise():
    tree = _mixed_tree(5)
    blob = ckpt_io.dumps(tree)
    assert isinstance(blob, bytes)
    _assert_bitwise(tree, ckpt_io.loads(blob, tree, numpy=True))
    _assert_bitwise(tree, ckpt_io.loads(blob, _skeleton(tree), numpy=True))


@needs_zstd
def test_dumps_compresses_redundant_payloads():
    tree = {"z": np.zeros((256, 256), np.float32)}
    blob = ckpt_io.dumps(tree)
    assert len(blob) < tree["z"].nbytes // 10


@needs_zstd
def test_loads_missing_leaf_raises():
    blob = ckpt_io.dumps({"a": np.ones(3, np.float32)})
    like = {"a": np.ones(3, np.float32), "b": np.ones(2, np.int32)}
    with pytest.raises(KeyError, match="missing leaf"):
        ckpt_io.loads(blob, like, numpy=True)


@needs_zstd
def test_save_load_file_roundtrip(tmp_path):
    tree = _mixed_tree(6)
    path = str(tmp_path / "nested" / "snap.zst")
    ckpt_io.save(path, tree)
    assert not (tmp_path / "nested" / "snap.zst.tmp").exists()  # atomic
    _assert_bitwise(tree, ckpt_io.load(path, tree, numpy=True))


@needs_zstd
def test_roundtrip_dataclass_tree():
    """Structured pytrees (the engine snapshots dataclass caches) survive:
    flatten-with-path keys the leaves, not the container type."""

    @jax.tree_util.register_dataclass
    @dataclasses.dataclass
    class Snap:
        k: np.ndarray
        v: np.ndarray

    tree = Snap(k=np.arange(12, dtype=np.float32).reshape(3, 4),
                v=np.arange(4, dtype=np.int32))
    back = ckpt_io.loads(ckpt_io.dumps(tree), _skeleton(tree), numpy=True)
    assert isinstance(back, Snap)
    _assert_bitwise(tree, back)
