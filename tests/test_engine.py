"""CortexEngine lifecycle + Prism singleton memory accounting (paper Eq. 1,
Tables 1/2 semantics) + router + server."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import CortexEngine
from repro.core.prism import Prism, tree_bytes
from repro.core.router import CortexRouter
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as model_lib
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchServer


def _engine(n_main=2, max_side=3, theta=-1.0, **kw):
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    prism = Prism(params, cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    eng = CortexEngine(
        prism, tok, n_main=n_main, max_side=max_side, main_capacity=256,
        side_max_steps=6, inject_tokens=8, theta=theta,
        sampling=SamplingParams(temperature=1.0), **kw,
    )
    return eng


def test_full_lifecycle_spawn_merge():
    eng = _engine()
    eng.submit("hello [TASK: verify this claim] world", lane=0)
    eng.submit("plain agent", lane=1)
    eng.run(40)
    events = [e["event"] for e in eng.history]
    assert "spawn" in events
    assert "merge" in events
    merge = next(e for e in eng.history if e["event"] == "merge")
    assert merge["accepted"] is True  # theta = -1 accepts everything


def test_gate_rejects_when_theta_high():
    eng = _engine(theta=2.0)  # cosine can never reach 2.0
    eng.submit("x [TASK: impossible standard] y", lane=0)
    eng.run(40)
    merges = [e for e in eng.history if e["event"] == "merge"]
    assert merges and all(m["accepted"] is False for m in merges)


def test_prism_weights_shared_not_copied():
    eng = _engine()
    eng.submit("agent zero", lane=0)
    eng.submit("agent one", lane=1)
    rep = eng.memory_report()
    # weights counted once, and the standard-architecture counterfactual
    # scales with agent count
    assert rep["weight_bytes"] == tree_bytes(eng.prism.params)
    assert rep["standard_architecture_bytes"] >= rep["weight_bytes"] * rep["n_agents"]
    # all agents literally hold the same buffers (singleton pattern)
    assert eng.prism.acquire("probe") is eng.prism.params


def test_marginal_agent_cost_is_synapse_sized():
    """Paper Table 2: adding a side agent costs ~Mem(synapse), not Mem(W)."""
    eng = _engine()
    eng.submit("main [TASK: one] t", lane=0)
    eng.run(3)  # spawn happens
    rep = eng.memory_report()
    side_bytes = [v for k, v in [(s.agent_id, 0) for s in eng.sides] if False]
    active_sides = [s for s in eng.sides if s.active]
    assert active_sides
    from repro.core.engine import _lane_slice
    per_side = tree_bytes(_lane_slice(eng.side_caches, active_sides[0].lane))
    assert per_side < rep["weight_bytes"] * 0.2  # << weights


def test_router_triggers_once():
    r = CortexRouter()
    text = "abc [TASK: find x] middle"
    t1 = r.scan("a", text)
    assert [x.kind for x in t1] == ["task"]
    assert t1[0].payload == "find x"
    t2 = r.scan("a", text)
    assert t2 == []
    t3 = r.scan("a", text + " tail [DONE]")
    assert [x.kind for x in t3] == ["done"]


def test_router_split_across_chunks():
    r = CortexRouter()
    assert r.scan("a", "xy [TAS") == []
    trig = r.scan("a", "xy [TASK: joined] z")
    assert [t.kind for t in trig] == ["task"]


def test_batch_server_completes_requests():
    cfg = get_config("qwen2.5-0.5b", reduced=True)
    params = model_lib.init_params(jax.random.key(0), cfg)
    tok = ByteTokenizer(cfg.vocab_size)
    srv = BatchServer(params, cfg, tok, n_lanes=2, capacity=128,
                      sampling=SamplingParams(temperature=1.0))
    for i in range(4):
        srv.submit(f"request number {i}", max_new_tokens=5)
    done = srv.run_until_done(max_ticks=200)
    assert len(done) == 4
    assert all(len(r.text) > 0 for r in done)


def test_side_agent_sees_compressed_context():
    """The side agent's synapse snapshot holds landmarks from the parent's
    prompt (lm_count > 0 right after spawn)."""
    eng = _engine()
    eng.submit("the quick brown fox [TASK: recall the animal] jumps", lane=0)
    eng.run(2)
    active = [s for s in eng.sides if s.active]
    assert active
    lane = active[0].lane
    lm_count = int(np.asarray(eng.side_caches.groups[0].lm_count)[0, lane])
    assert lm_count > 0
